//! The measured tuning path: real program variants as evaluation backends.
//!
//! Two ways to tune on real measurements, both over `runtime/{artifacts,
//! pjrt}`:
//!
//! - [`measure_kernel`] exhaustively times every variant and assembles a
//!   *measured* [`Cache`] — the paper's replayed-cachefile mode, which
//!   then flows through the registry/job-graph like any simulated space.
//! - [`MeasuredSource`] / [`MeasuredBackend`] implement the tuning
//!   [`EvalBackend`](crate::tuning::EvalBackend) seam *lazily*: an
//!   optimizer driving a `TuningContext` only compiles and times the
//!   variants it actually visits. The source memoizes measurements behind
//!   a mutex, so a job-graph fan-out of seeds over the same source
//!   measures each variant at most once (and hardware timing stays
//!   serialized, which keeps measurements clean).
//!
//! Measurement itself goes through the [`VariantRunner`] trait so tests
//! (and future non-PJRT runtimes) can substitute a deterministic runner.

use std::collections::{BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

use super::artifacts::{Artifact, ArtifactSet};
use super::pjrt::PjrtRuntime;
use crate::searchspace::{Param, ParamSet, SearchSpace};
use crate::tuning::cache::FAILURE_COST_S;
use crate::tuning::{Cache, EvalBackend};
use crate::util::error::{bail, Context, Result};

/// Cost estimate charged for a variant that has not been measured yet
/// (the budget planner needs *some* projection before the first compile).
pub const NOMINAL_EVAL_COST_S: f64 = 0.5;

/// Compiles and times one program variant: `(mean_ms, compile_s)`.
///
/// [`PjrtRuntime`] is the production implementation; tests plug in
/// deterministic fakes so the measured seam is exercised without PJRT.
pub trait VariantRunner: Sync {
    fn platform(&self) -> String;
    fn measure(
        &self,
        artifact: &Artifact,
        warmup: usize,
        reps: usize,
        seed: u64,
    ) -> Result<(f64, f64)>;
}

impl VariantRunner for PjrtRuntime {
    fn platform(&self) -> String {
        PjrtRuntime::platform(self)
    }

    fn measure(
        &self,
        artifact: &Artifact,
        warmup: usize,
        reps: usize,
        seed: u64,
    ) -> Result<(f64, f64)> {
        let (variant, inputs) = self.prepare(artifact, seed)?;
        let timing = variant.time(&inputs, warmup, reps)?;
        Ok((timing.mean_ms, variant.compile_s))
    }
}

/// Build the variant search space of one kernel from its artifacts: one
/// tunable parameter per manifest param key, values = distinct values seen.
/// Combinations not present in the manifest are hidden failures.
pub fn variant_space(kernel: &str, set: &ArtifactSet) -> Result<SearchSpace> {
    let artifacts = set.for_kernel(kernel);
    if artifacts.is_empty() {
        bail!("no artifacts for kernel '{}'", kernel);
    }
    let keys: Vec<String> = artifacts[0].params.keys().cloned().collect();
    let mut params = Vec::new();
    for key in &keys {
        let values: BTreeSet<i64> = artifacts
            .iter()
            .map(|a| *a.params.get(key).expect("inconsistent manifest params"))
            .collect();
        params.push(Param::ints(key, &values.into_iter().collect::<Vec<_>>()));
    }
    SearchSpace::build(&format!("{}-measured", kernel), ParamSet::new(params), &[])
        .map_err(crate::util::error::Error::msg)
}

/// One lazily-measured variant: observed value + actual evaluation cost.
#[derive(Debug, Clone, Copy)]
struct Measurement {
    value: Option<f64>,
    cost_s: f64,
}

/// A shareable source of measured evaluations for one kernel's variant
/// space: implements [`BackendSource`](crate::tuning::BackendSource), so
/// tuning jobs carry it exactly like a cached space. All backends minted
/// from one source share its measurement store.
pub struct MeasuredSource<'r> {
    runner: &'r dyn VariantRunner,
    space: Arc<SearchSpace>,
    /// Artifact per present config index; absent combos are hidden failures.
    by_index: HashMap<u32, Artifact>,
    warmup: usize,
    reps: usize,
    seed: u64,
    store: Mutex<HashMap<u32, Measurement>>,
    errors: Mutex<Vec<String>>,
}

impl<'r> MeasuredSource<'r> {
    pub fn new(
        runner: &'r dyn VariantRunner,
        set: &ArtifactSet,
        kernel: &str,
        warmup: usize,
        reps: usize,
        seed: u64,
    ) -> Result<MeasuredSource<'r>> {
        let space = Arc::new(variant_space(kernel, set)?);
        let mut by_index = HashMap::new();
        for artifact in set.for_kernel(kernel) {
            let cfg = config_of(artifact, &space);
            let idx = space
                .index_of(&cfg)
                .context("artifact config missing from variant space")?;
            by_index.insert(idx, artifact.clone());
        }
        Ok(MeasuredSource {
            runner,
            space,
            by_index,
            warmup,
            reps,
            seed,
            store: Mutex::new(HashMap::new()),
            errors: Mutex::new(Vec::new()),
        })
    }

    pub fn space(&self) -> &Arc<SearchSpace> {
        &self.space
    }

    /// Measure `i` (memoized). The store lock is held across the
    /// measurement on purpose: concurrent workers timing variants in
    /// parallel would contaminate each other's wall-clock samples.
    fn measure_config(&self, i: u32) -> Measurement {
        let mut store = self.store.lock().unwrap();
        if let Some(m) = store.get(&i) {
            return *m;
        }
        let m = match self.by_index.get(&i) {
            // A parameter combination no artifact covers: hidden failure.
            None => Measurement { value: None, cost_s: FAILURE_COST_S },
            Some(artifact) => {
                match self.runner.measure(artifact, self.warmup, self.reps, self.seed) {
                    Ok((mean_ms, compile_s)) => Measurement {
                        value: Some(mean_ms),
                        cost_s: compile_s + self.reps as f64 * mean_ms * 1e-3,
                    },
                    Err(e) => {
                        let mut errors = self.errors.lock().unwrap();
                        if errors.len() < 32 {
                            errors.push(format!("{}: {}", artifact.name, e));
                        }
                        Measurement { value: None, cost_s: FAILURE_COST_S }
                    }
                }
            }
        };
        store.insert(i, m);
        m
    }

    /// Number of variants measured (or failed) so far.
    pub fn measured_count(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// Measurement errors recorded so far (capped).
    pub fn errors(&self) -> Vec<String> {
        self.errors.lock().unwrap().clone()
    }

    /// Snapshot of measured variants: (artifact name, mean ms, cost s),
    /// successful measurements only, sorted ascending by runtime.
    pub fn results(&self) -> Vec<(String, f64, f64)> {
        let store = self.store.lock().unwrap();
        let mut out: Vec<(String, f64, f64)> = store
            .iter()
            .filter_map(|(i, m)| {
                let name = self.by_index.get(i)?.name.clone();
                m.value.map(|v| (name, v, m.cost_s))
            })
            .collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        out
    }
}

impl crate::tuning::BackendSource for MeasuredSource<'_> {
    fn backend(&self) -> Box<dyn EvalBackend + '_> {
        Box::new(MeasuredBackend { source: self })
    }

    fn space_id(&self) -> String {
        self.space.name.clone()
    }
}

/// Per-run view over a [`MeasuredSource`]: the lazy measured
/// [`EvalBackend`]. Stateless itself — measurements and costs live in the
/// shared source store, so repeated runs reuse every compile.
pub struct MeasuredBackend<'s> {
    source: &'s MeasuredSource<'s>,
}

impl<'s> MeasuredBackend<'s> {
    pub fn new(source: &'s MeasuredSource<'s>) -> MeasuredBackend<'s> {
        MeasuredBackend { source }
    }
}

impl EvalBackend for MeasuredBackend<'_> {
    fn space(&self) -> &Arc<SearchSpace> {
        &self.source.space
    }

    fn id(&self) -> String {
        self.source.space.name.clone()
    }

    fn eval_cost_s(&self, i: u32) -> f64 {
        match self.source.store.lock().unwrap().get(&i) {
            Some(m) => m.cost_s,
            None if self.source.by_index.contains_key(&i) => NOMINAL_EVAL_COST_S,
            None => FAILURE_COST_S,
        }
    }

    fn cost_model_exact(&self) -> bool {
        false
    }

    fn evaluate_batch(&mut self, configs: &[u32]) -> Vec<Option<f64>> {
        configs.iter().map(|&i| self.source.measure_config(i).value).collect()
    }
}

/// Result of exhaustively measuring a kernel's variants.
pub struct MeasuredSpace {
    pub cache: Cache,
    /// (artifact name, mean ms, compile s) per measured variant.
    pub measurements: Vec<(String, f64, f64)>,
}

/// Exhaustively measure all variants of `kernel` and assemble a measured
/// [`Cache`]. `warmup`/`reps` control per-variant timing.
pub fn measure_kernel(
    runtime: &PjrtRuntime,
    set: &ArtifactSet,
    kernel: &str,
    warmup: usize,
    reps: usize,
    seed: u64,
) -> Result<MeasuredSpace> {
    let space = std::sync::Arc::new(variant_space(kernel, set)?);
    let artifacts = set.for_kernel(kernel);

    // Map each artifact to its config index in the variant space.
    let mut mean_ms = vec![f32::INFINITY; space.len()];
    let mut compile_s = vec![0.2f32; space.len()]; // nominal for absent combos
    let mut measurements = Vec::with_capacity(artifacts.len());
    for artifact in &artifacts {
        let cfg: Vec<u16> = config_of(artifact, &space);
        let idx = space
            .index_of(&cfg)
            .expect("artifact config missing from variant space");
        let (mean, compile) = VariantRunner::measure(runtime, artifact, warmup, reps, seed)?;
        mean_ms[idx as usize] = mean as f32;
        compile_s[idx as usize] = compile as f32;
        measurements.push((artifact.name.clone(), mean, compile));
    }

    let cache = Cache::from_measured(space, mean_ms, compile_s, seed);
    Ok(MeasuredSpace { cache, measurements })
}

/// The value-index configuration of an artifact within the variant space.
pub fn config_of(artifact: &Artifact, space: &SearchSpace) -> Vec<u16> {
    space
        .params
        .params
        .iter()
        .map(|p| {
            let v = artifact.params[&p.name];
            p.values
                .iter()
                .position(|pv| pv.as_i64() == v)
                .expect("value missing from param domain") as u16
        })
        .collect()
}

/// Deterministic test doubles for the measured seam, shared by the unit
/// tests below and the integration suite (`rust/tests/`), which links the
/// library without `cfg(test)` — hence a regular public module.
pub mod testing {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Deterministic [`VariantRunner`]: runtime is a hash of the variant
    /// name, compile cost is fixed; counts `measure` calls so tests can
    /// assert measure-once memoization.
    #[derive(Default)]
    pub struct FakeRunner {
        calls: AtomicUsize,
    }

    impl FakeRunner {
        pub fn calls(&self) -> usize {
            self.calls.load(Ordering::Relaxed)
        }
    }

    impl VariantRunner for FakeRunner {
        fn platform(&self) -> String {
            "fake".into()
        }

        fn measure(
            &self,
            artifact: &Artifact,
            _warmup: usize,
            _reps: usize,
            _seed: u64,
        ) -> Result<(f64, f64)> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            let h = crate::util::rng::fnv1a(artifact.name.as_bytes());
            Ok((0.5 + (h % 64) as f64 / 16.0, 0.35))
        }
    }

    /// A manifest-less artifact for variant-space tests.
    pub fn fake_artifact(kernel: &str, params: &[(&str, i64)]) -> Artifact {
        let name = params
            .iter()
            .map(|(k, v)| format!("{}-{}", k, v))
            .collect::<Vec<_>>()
            .join("_");
        Artifact {
            kernel: kernel.into(),
            name: format!("{}__{}", kernel, name),
            path: PathBuf::from("/nonexistent"),
            params: params.iter().map(|(k, v)| (k.to_string(), *v)).collect::<BTreeMap<_, _>>(),
            inputs: vec![],
            n_outputs: 1,
        }
    }

    /// Three gemm artifacts over a 2×2 cartesian grid: the (32, 64)
    /// combination is an intentional gap (hidden failure).
    pub fn gemm_set_with_gap() -> ArtifactSet {
        ArtifactSet {
            artifacts: vec![
                fake_artifact("gemm", &[("block_m", 32), ("block_n", 32)]),
                fake_artifact("gemm", &[("block_m", 64), ("block_n", 32)]),
                fake_artifact("gemm", &[("block_m", 64), ("block_n", 64)]),
            ],
        }
    }

    /// A fully-covered gemm variant grid over the given parameter values.
    pub fn gemm_grid(block_ms: &[i64], block_ns: &[i64]) -> ArtifactSet {
        let mut artifacts = Vec::new();
        for &m in block_ms {
            for &n in block_ns {
                artifacts.push(fake_artifact("gemm", &[("block_m", m), ("block_n", n)]));
            }
        }
        ArtifactSet { artifacts }
    }
}

#[cfg(test)]
mod tests {
    use super::testing::{gemm_set_with_gap, FakeRunner};
    use super::*;
    use crate::tuning::{BackendSource, TuningContext};

    #[test]
    fn variant_space_from_manifest_params() {
        let set = gemm_set_with_gap();
        let space = variant_space("gemm", &set).unwrap();
        assert_eq!(space.dims(), 2);
        assert_eq!(space.len(), 4); // full cartesian; (32,64) will be a failure entry
        let cfg = config_of(&set.artifacts[1], &space);
        assert_eq!(space.params.describe(&cfg), "block_m=64, block_n=32");
        assert!(variant_space("missing", &set).is_err());
    }

    #[test]
    fn measured_source_is_lazy_and_memoized() {
        let set = gemm_set_with_gap();
        let runner = FakeRunner::default();
        let source = MeasuredSource::new(&runner, &set, "gemm", 1, 3, 42).unwrap();
        assert_eq!(source.space_id(), "gemm-measured");
        assert_eq!(source.measured_count(), 0, "nothing measured up front");

        let mut backend = source.backend();
        let i = *source.by_index.keys().next().unwrap();
        assert_eq!(backend.eval_cost_s(i), NOMINAL_EVAL_COST_S, "estimate before measuring");
        let v = backend.evaluate_one(i);
        assert!(v.is_some());
        assert!(
            backend.eval_cost_s(i) < NOMINAL_EVAL_COST_S,
            "actual (cheap fake) cost replaces the estimate after measuring"
        );
        // A second run over the same source reuses the measurement.
        let mut second = source.backend();
        assert_eq!(second.evaluate_one(i), v);
        assert_eq!(runner.calls(), 1, "memoized across runs");
        assert!(source.errors().is_empty());
    }

    #[test]
    fn absent_combo_is_hidden_failure() {
        let set = gemm_set_with_gap();
        let runner = FakeRunner::default();
        let source = MeasuredSource::new(&runner, &set, "gemm", 1, 3, 42).unwrap();
        let space = Arc::clone(source.space());
        let absent: Vec<u32> = space
            .iter_indices()
            .filter(|i| !source.by_index.contains_key(i))
            .collect();
        assert_eq!(absent.len(), 1, "(32, 64) has no artifact");
        let mut backend = source.backend();
        assert_eq!(backend.evaluate_one(absent[0]), None);
        assert_eq!(backend.eval_cost_s(absent[0]), FAILURE_COST_S);
        assert_eq!(runner.calls(), 0);
    }

    #[test]
    fn tuning_context_drives_measured_backend() {
        let set = gemm_set_with_gap();
        let runner = FakeRunner::default();
        let source = MeasuredSource::new(&runner, &set, "gemm", 1, 3, 7).unwrap();
        let mut backend = source.backend();
        let mut ctx = TuningContext::with_backend(backend.as_mut(), 1e6, 1);
        let all: Vec<u32> = ctx.space().iter_indices().collect();
        let values = ctx.evaluate_batch(&all);
        assert_eq!(values.iter().filter(|v| v.is_some()).count(), 3);
        let (_, best) = ctx.best().unwrap();
        let min = source.results().first().unwrap().1;
        assert_eq!(best, min, "context best equals cheapest measured variant");
        assert_eq!(runner.calls(), 3, "one compile per variant");
    }
}
