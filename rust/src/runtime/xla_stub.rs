//! API-compatible stand-in for the `xla` PJRT bindings.
//!
//! The offline build environment ships no `xla-rs`/`xla_extension`
//! bindings, so this module mirrors the exact surface `runtime::pjrt`
//! consumes. Data types ([`Literal`]) are real — `make_inputs` and the
//! tests that exercise it work unchanged — while execution entry points
//! ([`PjRtClient::cpu`]) report that the build has no PJRT support. A
//! build with the `pjrt` feature enabled (plus the vendored `xla` crate)
//! swaps this module out for the real bindings; see `runtime::pjrt`.

use crate::util::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "{}: built without PJRT support (enable the `pjrt` feature with the vendored `xla` bindings)",
        what
    ))
}

/// Element types [`Literal`] can hold (the subset the artifacts use).
/// Public only because the [`NativeType`] conversion trait names it in
/// its method signatures; not part of the mirrored `xla` surface.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: typed element storage plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    elements: Elements,
    dims: Vec<i64>,
}

/// Sealed-ish conversion trait backing `Literal::{vec1, to_vec}`.
pub trait NativeType: Sized {
    fn wrap(data: &[Self]) -> Elements;
    fn unwrap(elements: &Elements) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[f32]) -> Elements {
        Elements::F32(data.to_vec())
    }
    fn unwrap(elements: &Elements) -> Option<Vec<f32>> {
        match elements {
            Elements::F32(v) => Some(v.clone()),
            Elements::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[i32]) -> Elements {
        Elements::I32(data.to_vec())
    }
    fn unwrap(elements: &Elements) -> Option<Vec<i32>> {
        match elements {
            Elements::I32(v) => Some(v.clone()),
            Elements::F32(_) => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elements: T::wrap(data), dims: vec![data.len() as i64] }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if n != have {
            return Err(Error::msg(format!(
                "reshape to {:?} ({} elements) from {} elements",
                dims, n, have
            )));
        }
        Ok(Literal { elements: self.elements.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.elements {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
        }
    }

    /// Unwrap a 1-tuple output (identity here: the stub never produces
    /// tuples because it never executes).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    /// Typed element retrieval.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.elements).ok_or_else(|| Error::msg("literal holds a different dtype"))
    }
}

/// Parsed HLO module handle (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("parsing HLO text"))
    }
}

/// Computation handle (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("reading device buffer"))
    }
}

/// Loaded executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// PJRT client handle. `cpu()` fails in stub builds, so every measured
/// entry point degrades to a clean runtime error instead of a link error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err(), "dtype mismatch must error");
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn execution_paths_report_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("without PJRT support"), "{}", e);
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
