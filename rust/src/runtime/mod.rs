//! Runtime layer: PJRT CPU execution of AOT artifacts (L3 <- L2/L1 bridge)
//! and the measured tuning paths built on top of it — both the exhaustive
//! measured-cache path and the lazy [`MeasuredBackend`] evaluation backend
//! (see `crate::tuning::backend`). Builds without the `pjrt` feature use
//! an API-compatible stub for the `xla` bindings ([`xla_stub`]): data
//! plumbing works, execution reports a clean "no PJRT support" error.

pub mod artifacts;
pub mod measured;
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

pub use artifacts::{Artifact, ArtifactSet, TensorSpec};
pub use measured::{
    measure_kernel, variant_space, MeasuredBackend, MeasuredSource, MeasuredSpace, VariantRunner,
};
pub use measured::testing as measured_testing;
pub use pjrt::{gemm_reference, make_inputs, CompiledVariant, PjrtRuntime, Timing};
