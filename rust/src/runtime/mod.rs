//! Runtime layer: PJRT CPU execution of AOT artifacts (L3 <- L2/L1 bridge)
//! and the measured-cache tuning path built on top of it.

pub mod artifacts;
pub mod measured;
pub mod pjrt;

pub use artifacts::{Artifact, ArtifactSet, TensorSpec};
pub use measured::{measure_kernel, variant_space, MeasuredSpace};
pub use pjrt::{gemm_reference, make_inputs, CompiledVariant, PjrtRuntime, Timing};
