//! Meta-search strategies over a [`MetaTuning`] setup.
//!
//! Four families, all driving the same memoized meta-evaluation seam:
//!
//! - **Grid**: every meta-configuration at full seed strength.
//! - **Random**: a seeded distinct sample of the meta space.
//! - **Successive halving**: rungs of escalating seeds-per-evaluation; the
//!   top `1/eta` of each rung (ranked by score, ties by ordinal) advances
//!   until a single survivor is scored at full strength. Candidates are
//!   canonicalized (sorted, deduplicated) on entry, so rung survivors are
//!   a pure function of the candidate *set* — invariant to job ordering.
//! - **Search**: any registry optimizer run over the
//!   [`MetaBackend`](super::backend::MetaBackend) through a plain
//!   `TuningContext` — the repo's own optimizers tuning the repo's own
//!   optimizers — with a budget of `evals` meta-evaluations' worth of
//!   real tuning seconds.

use super::backend::{MetaResult, MetaTuning};
use crate::optimizers::OptimizerSpec;
use crate::tuning::TuningContext;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::table::{f, Table};

/// How to search the meta space.
#[derive(Debug, Clone)]
pub enum MetaStrategy {
    /// Exhaustive: every meta-configuration at full seed strength.
    Grid,
    /// A seeded distinct sample of `evals` meta-configurations.
    Random { evals: usize },
    /// Successive halving with reduction factor `eta` over `evals`
    /// starting candidates (the whole space when `evals` covers it).
    Sha { eta: usize, evals: usize },
    /// A registry optimizer over the meta backend, budgeted to `evals`
    /// fresh meta-evaluations.
    Search { spec: OptimizerSpec, evals: usize },
}

impl MetaStrategy {
    /// Parse the CLI's `--meta` value: `grid`, `random`, `sha`, or any
    /// optimizer spec the registry accepts (e.g. `sa` or `ga:elites=3`).
    pub fn parse(s: &str, evals: usize) -> Option<MetaStrategy> {
        match s {
            "grid" => Some(MetaStrategy::Grid),
            "random" => Some(MetaStrategy::Random { evals }),
            "sha" => Some(MetaStrategy::Sha { eta: 3, evals }),
            other => OptimizerSpec::parse(other).map(|spec| MetaStrategy::Search { spec, evals }),
        }
    }

    /// Stable label for reports.
    pub fn label(&self) -> String {
        match self {
            MetaStrategy::Grid => "grid".into(),
            MetaStrategy::Random { evals } => format!("random:{}", evals),
            MetaStrategy::Sha { eta, evals } => format!("sha:eta={},evals={}", eta, evals),
            MetaStrategy::Search { spec, evals } => format!("search:{}(evals={})", spec, evals),
        }
    }
}

/// One successive-halving rung: the candidates scored at `runs` seeds and
/// the survivors advanced to the next rung (both in ascending ordinal
/// order).
#[derive(Debug, Clone, PartialEq)]
pub struct Rung {
    pub runs: usize,
    pub candidates: Vec<u32>,
    pub survivors: Vec<u32>,
}

/// The outcome of one sweep: the ranked leaderboard of everything
/// evaluated, plus the rung trace for successive halving.
#[derive(Debug)]
pub struct SweepOutcome {
    /// [`MetaStrategy::label`] of the strategy that ran.
    pub strategy: String,
    /// All evaluated configs, best first (see [`MetaTuning::leaderboard`]).
    pub leaderboard: Vec<MetaResult>,
    /// Successive-halving rungs (empty for the other strategies).
    pub rungs: Vec<Rung>,
}

/// Run one meta-search strategy to completion. Deterministic: the outcome
/// is a pure function of `(mt setup, strategy, seed)` — scheduler width
/// never changes it.
pub fn sweep(mt: &MetaTuning, strategy: &MetaStrategy, seed: u64) -> SweepOutcome {
    let rungs = match strategy {
        MetaStrategy::Grid => {
            let all: Vec<u32> = (0..mt.space().len() as u32).collect();
            mt.evaluate_all(&all, mt.runs());
            Vec::new()
        }
        MetaStrategy::Random { evals } => {
            let cands = sample_ordinals(mt, *evals, seed);
            mt.evaluate_all(&cands, mt.runs());
            Vec::new()
        }
        MetaStrategy::Sha { eta, evals } => {
            let cands = sample_ordinals(mt, *evals, seed);
            successive_halving(mt, cands, *eta)
        }
        MetaStrategy::Search { spec, evals } => {
            let budget_s = mt.meta_eval_cost_s() * (*evals).max(1) as f64;
            let mut backend = mt.backend();
            let mut ctx = TuningContext::with_backend(backend.as_mut(), budget_s, seed);
            spec.build().run(&mut ctx);
            Vec::new()
        }
    };
    SweepOutcome { strategy: strategy.label(), leaderboard: mt.leaderboard(), rungs }
}

/// A canonical (ascending) candidate list: the whole space when `evals`
/// covers it, else a seeded distinct sample (`evals == 0` samples
/// nothing — the CLI rejects it before it gets here).
fn sample_ordinals(mt: &MetaTuning, evals: usize, seed: u64) -> Vec<u32> {
    let n = mt.space().len();
    if evals >= n {
        return (0..n as u32).collect();
    }
    let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
    let mut sample = mt.space().random_sample(&mut rng, evals);
    sample.sort_unstable();
    sample
}

/// The halving keep-count: how many of `n` candidates survive one rung at
/// reduction factor `eta` — the top `⌈n/eta⌉`, collapsing to a single
/// survivor once the field is down to one. Shared by [`successive_halving`]
/// and the racing ladder (`crate::coordinator::race`), so both elimination
/// schedules stay the same function.
pub fn halving_keep(n: usize, eta: usize) -> usize {
    let eta = eta.max(2);
    if n > 1 {
        n.div_ceil(eta)
    } else {
        1
    }
}

/// Successive halving with seeds-per-rung escalation: rung `k` of `L`
/// evaluates its candidates at `min(runs, max(runs / eta^(L−k), eta^k))`
/// seeds — the budget-scaled schedule, floored by `eta^k` so every
/// pre-final rung adds seeds even when `runs` is small relative to the
/// candidate count (without the floor, `runs=5, eta=3` over 16 candidates
/// clamps every early rung to a single seed and eliminates on
/// single-seed noise) — and advances the top `⌈n/eta⌉` (score
/// descending, ties by ascending ordinal); the final survivor is scored
/// at the full run count. Escalation reuses lower-rung curves from the
/// memo, so each rung pays only for its new seed indices. Candidates are
/// sorted and deduplicated first, so the rung trace is invariant to the
/// order candidates were supplied in.
pub fn successive_halving(mt: &MetaTuning, mut cands: Vec<u32>, eta: usize) -> Vec<Rung> {
    let eta = eta.max(2);
    cands.sort_unstable();
    cands.dedup();
    if cands.is_empty() {
        return Vec::new();
    }
    let final_runs = mt.runs();
    // Rungs needed to reduce the field to one survivor.
    let mut levels = 0usize;
    let mut m = cands.len();
    while m > 1 {
        m = m.div_ceil(eta);
        levels += 1;
    }
    let mut rungs = Vec::with_capacity(levels + 1);
    for k in 0..=levels {
        let budget_scaled =
            (final_runs / eta.saturating_pow((levels - k) as u32).max(1)).max(1);
        let escalation_floor = eta.saturating_pow(k as u32).min(final_runs);
        let r = budget_scaled.max(escalation_floor).min(final_runs);
        let scores = mt.evaluate_all(&cands, r);
        if mt.interrupted() {
            // A fired cancel token cut the rung short: stored curves are a
            // completed prefix and the scores partial — eliminating on
            // them would be noise, so stop escalating. The rung trace ends
            // at the last fully-scored rung.
            break;
        }
        let mut ranked: Vec<(u32, f64)> =
            cands.iter().copied().zip(scores.iter().map(|s| s.score)).collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let keep = halving_keep(cands.len(), eta);
        let mut survivors: Vec<u32> = ranked.iter().take(keep).map(|&(o, _)| o).collect();
        survivors.sort_unstable();
        rungs.push(Rung { runs: r, candidates: cands.clone(), survivors: survivors.clone() });
        cands = survivors;
    }
    rungs
}

/// Render the sweep leaderboard (top `top` rows) for the CLI.
pub fn leaderboard_table(title: &str, leaderboard: &[MetaResult], top: usize) -> Table {
    let mut t = Table::new(title, &["Rank", "Spec", "Seeds", "Score P"]);
    for (i, r) in leaderboard.iter().take(top).enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            r.spec.to_string(),
            format!("{}", r.runs),
            f(r.score, 3),
        ]);
    }
    t
}

/// The sweep's grid header — the fields shared verbatim by the full
/// report and the per-shard partials (so `merge` can prove all partials
/// describe the same sweep by exact comparison).
fn sweep_header(mt: &MetaTuning, strategy: &str, seed: u64) -> Json {
    let mut j = Json::obj();
    j.set("base", mt.base().to_string());
    j.set("strategy", strategy);
    j.set("spaces", Json::Arr(mt.space_ids().into_iter().map(Json::from).collect()));
    j.set("runs", mt.runs());
    j.set("seed", seed);
    j.set("meta_space_size", mt.space().len());
    j
}

/// One leaderboard row. `ordinal` is carried only in shard partials —
/// the merger needs it to prove coverage and to re-sort exactly as
/// [`MetaTuning::leaderboard`] does — and stripped on merge, so the full
/// report never shows it.
fn result_row(r: &MetaResult, with_ordinal: bool) -> Json {
    let mut row = Json::obj();
    if with_ordinal {
        row.set("ordinal", r.ordinal as u64);
    }
    row.set("spec", r.spec.to_string());
    let mut ov = Json::obj();
    for (k, v) in &r.overrides {
        ov.set(k, *v);
    }
    row.set("overrides", ov);
    row.set("runs", r.runs);
    row.set("score", r.score);
    row.set("per_space", r.per_space.clone());
    row
}

/// The sweep report as JSON — every field a pure function of the sweep
/// inputs (no wall-clock, no thread counts), so files are byte-identical
/// for any `--threads` width. Shares [`crate::util::json::write_file`]
/// with `coordinate --out`.
pub fn sweep_json(mt: &MetaTuning, outcome: &SweepOutcome, seed: u64) -> Json {
    let mut j = sweep_header(mt, &outcome.strategy, seed);
    // An interrupted sweep (Ctrl-C, or a served session's `cancel`) is
    // flagged so the completed-prefix leaderboard below can never pass as
    // a full result; uninterrupted reports omit the key, keeping their
    // bytes identical to pre-cancellation builds.
    if mt.interrupted() {
        j.set("interrupted", true);
    }
    // Inner-job completion counters: partial sweeps (a cancelled or
    // partly-failed run) stay diffable against full ones.
    j.set("jobs", mt.jobs_summary().to_json());
    let rows: Vec<Json> = outcome.leaderboard.iter().map(|r| result_row(r, false)).collect();
    j.set("leaderboard", Json::Arr(rows));
    if !outcome.rungs.is_empty() {
        let ordinals = |os: &[u32]| Json::Arr(os.iter().map(|&o| Json::from(o as u64)).collect());
        let mut rs: Vec<Json> = Vec::with_capacity(outcome.rungs.len());
        for rung in &outcome.rungs {
            let mut o = Json::obj();
            o.set("runs", rung.runs);
            o.set("candidates", ordinals(&rung.candidates));
            o.set("survivors", ordinals(&rung.survivors));
            rs.push(o);
        }
        j.set("rungs", Json::Arr(rs));
    }
    j
}

/// The partial report of one `sweep --meta grid --shard K/N` run: the
/// sweep header, this shard's `"jobs"` counters, and the leaderboard rows
/// of the meta-ordinals it owns (each tagged with its ordinal for the
/// merger). Grid only — the adaptive strategies (random with shared seed
/// is fine, but sha/search choose later evaluations from earlier scores)
/// have no up-front partition, and the CLI rejects them.
pub fn sweep_partial_json(
    mt: &MetaTuning,
    outcome: &SweepOutcome,
    seed: u64,
    shard: &crate::coordinator::ShardSpec,
) -> Json {
    let mut j = Json::obj();
    j.set("partial", "sweep");
    let header = sweep_header(mt, &outcome.strategy, seed);
    if let Json::Obj(pairs) = header {
        for (k, v) in pairs {
            j.set(&k, v);
        }
    }
    j.set("shard", shard.to_json());
    j.set("jobs", mt.jobs_summary().to_json());
    let rows: Vec<Json> = outcome.leaderboard.iter().map(|r| result_row(r, true)).collect();
    j.set("leaderboard", Json::Arr(rows));
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parsing() {
        assert!(matches!(MetaStrategy::parse("grid", 8), Some(MetaStrategy::Grid)));
        assert!(matches!(
            MetaStrategy::parse("random", 8),
            Some(MetaStrategy::Random { evals: 8 })
        ));
        assert!(matches!(
            MetaStrategy::parse("sha", 8),
            Some(MetaStrategy::Sha { eta: 3, evals: 8 })
        ));
        match MetaStrategy::parse("sa", 4) {
            Some(MetaStrategy::Search { spec, evals: 4 }) => assert_eq!(spec.label(), "sa"),
            other => panic!("expected Search, got {:?}", other),
        }
        assert!(MetaStrategy::parse("not_an_optimizer", 4).is_none());
        // Off-grid overrides fail at strategy parse time too.
        assert!(MetaStrategy::parse("sa:alpha=0.123", 4).is_none());
    }

    #[test]
    fn halving_keep_matches_the_sha_rule() {
        assert_eq!(halving_keep(16, 2), 8);
        assert_eq!(halving_keep(9, 3), 3);
        assert_eq!(halving_keep(4, 3), 2); // ceil
        assert_eq!(halving_keep(2, 3), 1);
        assert_eq!(halving_keep(1, 3), 1); // lone survivor stays
        assert_eq!(halving_keep(8, 0), 4); // eta clamps to 2
    }
}
