//! The meta evaluation backend: one meta-configuration costs a grid of
//! seeded tuning runs.
//!
//! [`MetaTuning`] is the shared setup of one sweep — base spec, inner
//! `(application, GPU)` spaces, seeds-per-evaluation, base seed — plus a
//! memo store of already-collapsed scores. It implements
//! [`BackendSource`], minting [`MetaBackend`]s that implement
//! [`EvalBackend`] over the meta search space, so a plain
//! [`TuningContext`](crate::tuning::TuningContext) — and therefore any
//! registry optimizer — can drive the sweep.
//!
//! ## Determinism contract
//!
//! Evaluating meta-configuration `o` expands the base spec with `o`'s
//! decoded overrides and streams one `runs × spaces` batch of
//! [`TuningJob`]s through the sweep's shared, bounded [`Executor`] — the
//! nested fan-out path (rung escalations carry higher
//! [`Priority`](crate::coordinator::Priority): their scores gate the next
//! elimination). Inner seeds derive from [`meta_seed`]`(base, o)` and the
//! job's grid coordinates, **never** from execution order, worker
//! identity or priority, so sweep output is byte-identical for any
//! `--threads` width and any priority assignment.
//! `meta_seed(base, 0) == base` (the SplitMix64 finalizer fixes zero),
//! which pins the golden equivalence: a grid-of-one sweep issues exactly
//! the jobs `coordinate` would issue for the same spec, seed and spaces.
//!
//! ## Cost accounting
//!
//! One meta-evaluation's [`EvalBackend::eval_cost_s`] is the real
//! (simulated) tuning budget it consumes — `runs × Σ` inner space budgets
//! — so meta-budgets are honest: a meta-optimizer given a budget of `k`
//! meta-evaluations' worth of seconds performs `k` fresh evaluations.
//! Per-run curves are memoized per ordinal: revisits never recompute, and
//! a successive-halving rung escalation runs only the *new* seed indices,
//! reusing every lower-rung curve (seeds are per-run-index, so a prefix
//! of the stored curves is bit-identical to a fresh lower-rung grid).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::space::{decode, meta_space};
use crate::coordinator::{
    collate_groups, job_seed, BatchRunner, Executor, FnSource, JobsSummary, OwnedJob, Progress,
    SpaceEntry, TuningJob,
};
use crate::methodology::{aggregate, OptimizerFactory};
use crate::obs;
use crate::optimizers::OptimizerSpec;
use crate::searchspace::SearchSpace;
use crate::tuning::{BackendSource, EvalBackend};
use crate::util::cancel::CancelToken;
use crate::util::rng::avalanche;

/// A sweep-level progress consumer (Send so the sweep setup can move
/// across threads, Sync because executor workers call it concurrently).
pub type SweepProgress = Box<dyn Fn(&Progress) + Send + Sync>;

/// Base seed of one meta-configuration's inner tuning grid: the sweep seed
/// decorrelated by the meta-config *ordinal* (never by execution order).
/// `avalanche(0) == 0`, so ordinal 0 inherits the sweep seed unchanged —
/// the grid-of-one ≡ `coordinate` equivalence relies on this fixed point.
pub fn meta_seed(base: u64, ordinal: u64) -> u64 {
    base ^ avalanche(ordinal)
}

/// The collapsed outcome of one meta-evaluation: the aggregate performance
/// score P over the inner spaces, plus the per-space scalar scores.
#[derive(Debug, Clone, PartialEq)]
pub struct MetaScore {
    pub score: f64,
    pub per_space: Vec<f64>,
}

/// One leaderboard entry of a sweep (see [`MetaTuning::leaderboard`]).
#[derive(Debug, Clone)]
pub struct MetaResult {
    /// Index into the meta search space.
    pub ordinal: u32,
    /// The fully-expanded spec (base + decoded overrides).
    pub spec: OptimizerSpec,
    /// The decoded overrides alone, in domain order.
    pub overrides: Vec<(String, f64)>,
    /// Seeds this entry was (last) evaluated with — its highest rung.
    pub runs: usize,
    /// Aggregate score P at that run count (higher is better).
    pub score: f64,
    /// Per-space scalar scores, in sweep space order.
    pub per_space: Vec<f64>,
}

/// Shared setup and memo store of one hyperparameter sweep.
pub struct MetaTuning {
    base: OptimizerSpec,
    entries: Vec<Arc<SpaceEntry>>,
    runs: usize,
    seed: u64,
    /// The one bounded executor every nested fan-out of this sweep drains
    /// through — meta-batches share its width, queue bound and cancel
    /// token instead of spawning ad-hoc per-batch scopes.
    executor: Executor,
    /// Alternative execution engine ([`MetaTuning::with_runner`]): when
    /// set, inner batches are materialized as [`OwnedJob`]s and drained
    /// through it instead of the executor — the serve daemon's persistent
    /// pool path. Both engines receive the identical slot-ordered job
    /// sequence, so sweep output is bit-identical either way.
    runner: Option<Arc<dyn BatchRunner>>,
    /// Optional consumer of the inner jobs' progress events (the CLI's
    /// live sweep line).
    progress: Option<SweepProgress>,
    space: Arc<SearchSpace>,
    /// Per-ordinal memo: `store[o][si]` holds the curves of space `si`'s
    /// runs 0..k, grown monotonically as rungs escalate.
    store: Mutex<HashMap<u32, Vec<Vec<Vec<f64>>>>>,
    /// Cumulative completion counters over every inner job batch (the
    /// `sweep --out` `"jobs"` block).
    jobs_done: Mutex<JobsSummary>,
    hits: AtomicUsize,
    fresh: AtomicUsize,
    /// Latched when a batch was cut short by a fired cancel token: the
    /// sweep's stored curves cover a completed prefix only, and scores
    /// derived from them are partial (see [`MetaTuning::interrupted`]).
    interrupted: AtomicBool,
}

impl MetaTuning {
    /// Set up a sweep of `base`'s unpinned hyperparameters over `entries`,
    /// collapsing each meta-evaluation from `runs` seeds per space.
    /// Overrides already on `base` pin their keys (excluded from the meta
    /// space, applied to every expanded spec). Genome specs carry their
    /// parameters inside the genome and cannot be swept.
    pub fn new(
        base: OptimizerSpec,
        entries: Vec<Arc<SpaceEntry>>,
        runs: usize,
        seed: u64,
        threads: Option<usize>,
    ) -> Result<MetaTuning, String> {
        let OptimizerSpec::Named { overrides, .. } = &base else {
            return Err("genome specs have no hyperparameter domains to sweep".into());
        };
        if entries.is_empty() {
            return Err("sweep needs at least one (application, GPU) space".into());
        }
        let pinned: Vec<String> = overrides.iter().map(|(k, _)| k.clone()).collect();
        let domains = base.build().hyperparam_domains();
        let space = Arc::new(meta_space(&base.label(), domains, &pinned));
        Ok(MetaTuning {
            base,
            entries,
            runs: runs.max(1),
            seed,
            // Fail fast: evaluate_all's expect_curves discards the batch
            // on failure anyway (the abort latch is per-run, so the
            // shared executor is not poisoned for later batches).
            executor: Executor::with_threads(threads).fail_fast(),
            runner: None,
            progress: None,
            space,
            store: Mutex::new(HashMap::new()),
            jobs_done: Mutex::new(JobsSummary::default()),
            hits: AtomicUsize::new(0),
            fresh: AtomicUsize::new(0),
            interrupted: AtomicBool::new(false),
        })
    }

    /// Stream the inner jobs' [`Progress`] events to `sink` (executor
    /// workers call it concurrently). Events only observe; consumer timing
    /// never changes sweep output.
    pub fn with_progress(mut self, sink: SweepProgress) -> MetaTuning {
        self.progress = Some(sink);
        self
    }

    /// Cancel the sweep's own executor through `token` instead of a
    /// private one — the CLI's SIGINT seam
    /// ([`crate::util::signal::install_sigint`]). Irrelevant once
    /// [`Self::with_runner`] installs an external runner (the runner's
    /// token governs then — see [`Self::cancel_token`]).
    pub fn with_cancel(mut self, token: CancelToken) -> MetaTuning {
        self.executor = self.executor.cancel_via(token);
        self
    }

    /// Drain inner batches through `runner` instead of the sweep's own
    /// executor — the serve daemon hands every session's `MetaTuning` its
    /// shared pool (wrapped with the session's cancel token and priority
    /// band) so one process-wide worker set multiplexes all sweeps.
    pub fn with_runner(mut self, runner: Arc<dyn BatchRunner>) -> MetaTuning {
        self.runner = Some(runner);
        self
    }

    /// The token that cancels this sweep's inner batches — the runner's
    /// (per-session, under the daemon) when one is installed, else the
    /// shared executor's.
    pub fn cancel_token(&self) -> CancelToken {
        match &self.runner {
            Some(r) => r.batch_cancel_token(),
            None => self.executor.cancel_token(),
        }
    }

    /// Whether any inner batch was cut short by a fired cancel token. Once
    /// set, stored curves cover a completed prefix only: strategies stop
    /// escalating and report consumers must present the outcome as
    /// partial.
    pub fn interrupted(&self) -> bool {
        self.interrupted.load(Ordering::SeqCst)
    }

    /// Cumulative `{completed, cancelled, failed}` counters over every
    /// inner job batch this sweep has drained.
    pub fn jobs_summary(&self) -> JobsSummary {
        *self.jobs_done.lock().unwrap()
    }

    /// The meta search space under sweep.
    pub fn space(&self) -> &Arc<SearchSpace> {
        &self.space
    }

    /// The base spec (pinned overrides included).
    pub fn base(&self) -> &OptimizerSpec {
        &self.base
    }

    /// Seeds per meta-evaluation at full strength (the final SHA rung).
    pub fn runs(&self) -> usize {
        self.runs
    }

    /// Inner space identifiers, in sweep order.
    pub fn space_ids(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.cache.id()).collect()
    }

    /// The fully-expanded spec of meta-configuration `ordinal`.
    pub fn spec_for(&self, ordinal: u32) -> OptimizerSpec {
        let mut spec = self.base.clone();
        for (k, v) in decode(&self.space, ordinal) {
            spec = spec.try_with_override(k, v).expect("named base spec takes overrides");
        }
        spec
    }

    /// Real (simulated) tuning budget one full-strength meta-evaluation
    /// consumes: `runs × Σ` inner space budgets.
    pub fn meta_eval_cost_s(&self) -> f64 {
        self.runs as f64 * self.entries.iter().map(|e| e.setup.budget_s).sum::<f64>()
    }

    /// Memo hits so far: queries answered entirely from stored curves —
    /// meta-optimizer revisits and lower-rung re-queries recompute
    /// nothing.
    pub fn memo_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Fresh meta-evaluations so far — grid expansions that actually ran
    /// tuning jobs (a rung escalation that only adds seed indices counts
    /// once; memo hits do not count).
    pub fn evaluations(&self) -> usize {
        self.fresh.load(Ordering::Relaxed)
    }

    /// Score of one ordinal from the first `runs` stored curves per space.
    fn score_prefix(stored: &[Vec<Vec<f64>>], runs: usize) -> MetaScore {
        let per_space: Vec<Vec<Vec<f64>>> =
            stored.iter().map(|rs| rs[..runs.min(rs.len())].to_vec()).collect();
        let agg = aggregate(&per_space);
        MetaScore { score: agg.score, per_space: agg.per_space_scores }
    }

    /// Evaluate meta-configurations at `runs` seeds each; returns one
    /// [`MetaScore`] per ordinal, in input order. Ordinals whose stored
    /// curves don't yet cover `runs` expand into one flat
    /// `ordinals × spaces × missing-seeds` job batch drained by a single
    /// scheduler pool — the nested fan-out under a meta-optimizer's own
    /// `evaluate_batch`. Already-stored runs are never re-executed:
    /// per-job seeds depend only on the run index, so the stored prefix
    /// is bit-identical to a fresh lower-rung grid.
    pub fn evaluate_all(&self, ordinals: &[u32], runs: usize) -> Vec<MetaScore> {
        let runs = runs.max(1);
        // (ordinal, runs already stored) pairs that need more seeds.
        let mut missing: Vec<(u32, usize)> = Vec::new();
        {
            let store = self.store.lock().unwrap();
            let mut queued: HashSet<u32> = HashSet::new();
            for &o in ordinals {
                let have = store.get(&o).map(|s| s[0].len()).unwrap_or(0);
                if have >= runs {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    obs::counter("hypertune.memo_hits", 1);
                } else if queued.insert(o) {
                    missing.push((o, have));
                }
            }
        }
        if !missing.is_empty() {
            self.fresh.fetch_add(missing.len(), Ordering::Relaxed);
            let specs: Vec<OptimizerSpec> =
                missing.iter().map(|&(o, _)| self.spec_for(o)).collect();
            let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
            let base_seeds: Vec<u64> =
                missing.iter().map(|&(o, _)| meta_seed(self.seed, o as u64)).collect();
            let space_ids: Vec<String> = self.entries.iter().map(|e| e.cache.id()).collect();
            let n_spaces = self.entries.len();
            // Flat-offset table over the irregular fan-out: meta-config
            // `mi` contributes `n_spaces × (runs − have)` jobs (only the
            // missing seed indices), streamed lazily to the executor.
            let mut offsets = Vec::with_capacity(missing.len() + 1);
            let mut total = 0usize;
            offsets.push(0);
            for &(_, have) in &missing {
                total += n_spaces * (runs - have);
                offsets.push(total);
            }
            // The one flat-index decode both execution paths share: job
            // `i` belongs to meta-config `mi`, inner space `si`, run
            // index `r` (the config already holds `have` stored runs).
            let decode_at = |i: usize| {
                let mi = offsets.partition_point(|&off| off <= i) - 1;
                let (_, have) = missing[mi];
                let per = runs - have;
                let local = i - offsets[mi];
                let (si, r) = (local / per, have + local % per);
                (mi, si, r, have)
            };
            let noop = |_: &Progress| {};
            let sink: &(dyn Fn(&Progress) + Sync) = match &self.progress {
                Some(b) => b.as_ref(),
                None => &noop,
            };
            // Meta-eval fan-out span: how many configs expanded into how
            // many inner jobs; per-ordinal expansion marks carry the rung
            // each config escalates from.
            let mut meta_span = obs::span("hypertune.meta_eval")
                .kv("ordinals", missing.len())
                .kv("jobs", total)
                .kv("runs", runs);
            if obs::enabled() {
                obs::counter("hypertune.fresh_evals", missing.len() as u64);
                for &(o, have) in &missing {
                    drop(obs::span("hypertune.expand").kv("ordinal", o).kv("from_runs", have));
                }
            }
            let batch = match &self.runner {
                // Served path: the identical slot sequence, materialized
                // as owned jobs for the daemon's long-lived pool.
                Some(runner) => {
                    let spec_arcs: Vec<Arc<OptimizerSpec>> =
                        specs.iter().map(|s| Arc::new(s.clone())).collect();
                    let jobs: Vec<OwnedJob> = (0..total)
                        .map(|i| {
                            let (mi, si, r, have) = decode_at(i);
                            OwnedJob {
                                entry: Arc::clone(&self.entries[si]),
                                spec: Arc::clone(&spec_arcs[mi]),
                                seed: job_seed(
                                    base_seeds[mi],
                                    &space_ids[si],
                                    &labels[mi],
                                    r as u64,
                                ),
                                group: mi * n_spaces + si,
                                priority: have as i64,
                            }
                        })
                        .collect();
                    runner.run_batch(&jobs, sink)
                }
                None => {
                    let mut source = FnSource::new(total, |i| {
                        let (mi, si, r, have) = decode_at(i);
                        let e = &self.entries[si];
                        crate::coordinator::SourcedJob {
                            job: TuningJob {
                                source: &e.cache,
                                setup: &e.setup,
                                factory: &specs[mi] as &dyn OptimizerFactory,
                                seed: job_seed(
                                    base_seeds[mi],
                                    &space_ids[si],
                                    &labels[mi],
                                    r as u64,
                                ),
                                group: mi * n_spaces + si,
                            },
                            // Rung escalations (configs that already hold
                            // stored curves) outrank fresh candidates:
                            // their scores gate the next elimination.
                            // Execution order only — seeds are
                            // grid-derived, so scores never move.
                            priority: have as i64,
                        }
                    });
                    self.executor.run_observed(&mut source, sink)
                }
            };
            let summary = batch.summary();
            meta_span.note("completed", summary.completed);
            drop(meta_span);
            self.jobs_done.lock().unwrap().absorb(summary);
            let cut_short = !batch.fully_drained() || summary.cancelled > 0;
            if cut_short && summary.failed == 0 && self.cancel_token().is_cancelled() {
                // Interrupted by the cancel token (Ctrl-C, or a session
                // `cancel` under the daemon): keep every completed curve —
                // each bit-identical to its drain-all counterpart — filed
                // at its run index, and latch the partial state.
                self.interrupted.store(true, Ordering::SeqCst);
                let mut store = self.store.lock().unwrap();
                for h in &batch.handles {
                    if let Some(curve) = h.outcome.curve() {
                        let (mi, si, r, _) = decode_at(h.slot);
                        let (o, _) = missing[mi];
                        let stored = store
                            .entry(o)
                            .or_insert_with(|| vec![Vec::new(); self.entries.len()]);
                        // Append only at exactly the next free run index
                        // (handles are slot-ordered, so `r` ascends within
                        // each (config, space) group); curves after a gap
                        // are dropped — a stored prefix must stay a prefix.
                        if r == stored[si].len() {
                            stored[si].push(curve.to_vec());
                        }
                    }
                }
            } else {
                let groups = batch.groups();
                let grouped =
                    collate_groups(missing.len() * n_spaces, &groups, batch.expect_curves());
                let mut it = grouped.into_iter();
                let mut store = self.store.lock().unwrap();
                for &(o, have) in &missing {
                    let stored = store
                        .entry(o)
                        .or_insert_with(|| vec![Vec::new(); self.entries.len()]);
                    for space_runs in stored.iter_mut() {
                        // Each computed curve belongs at run index `have + j`.
                        // Append only at exactly the next free slot: a racing
                        // caller may have stored some of these runs already
                        // (bit-identical — seeds are per-run-index), and blind
                        // appends would file curves under the wrong index.
                        for (j, curve) in it
                            .next()
                            .expect("collate group per (ordinal, space)")
                            .into_iter()
                            .enumerate()
                        {
                            if have + j == space_runs.len() {
                                space_runs.push(curve);
                            }
                        }
                    }
                }
            }
        }
        let store = self.store.lock().unwrap();
        ordinals
            .iter()
            .map(|&o| match store.get(&o) {
                // The uninterrupted invariant: every queried ordinal holds
                // at least `runs` stored runs per space, so this arm is
                // exactly the old unconditional `score_prefix(_, runs)`.
                // After an interruption some ordinals hold a shorter
                // completed prefix (scored over what exists) or nothing at
                // all (NaN — the sweep is winding down; leaderboards skip
                // unevaluated ordinals entirely).
                Some(stored) if stored.iter().all(|rs| !rs.is_empty()) => {
                    let avail =
                        stored.iter().map(|rs| rs.len()).min().unwrap_or(0).min(runs);
                    Self::score_prefix(stored, avail)
                }
                _ => MetaScore {
                    score: f64::NAN,
                    per_space: vec![f64::NAN; self.entries.len()],
                },
            })
            .collect()
    }

    /// Everything evaluated so far, each ordinal at its highest run count,
    /// ranked by score (descending; ties broken by ascending ordinal, so
    /// the ranking is a pure function of the evaluated set). After an
    /// interruption, an ordinal is ranked over the balanced completed
    /// prefix its spaces share (the minimum stored run count); ordinals
    /// with no completed run on some space are omitted — a partial
    /// leaderboard shows only what was actually scored. Uninterrupted
    /// sweeps store equal run counts everywhere, so the minimum is the
    /// old `stored[0].len()` exactly.
    pub fn leaderboard(&self) -> Vec<MetaResult> {
        let store = self.store.lock().unwrap();
        let mut out: Vec<MetaResult> = store
            .iter()
            .filter_map(|(&o, stored)| {
                let runs = stored.iter().map(|rs| rs.len()).min().unwrap_or(0);
                if runs == 0 {
                    return None;
                }
                let s = Self::score_prefix(stored, runs);
                Some(MetaResult {
                    ordinal: o,
                    spec: self.spec_for(o),
                    overrides: decode(&self.space, o),
                    runs,
                    score: s.score,
                    per_space: s.per_space,
                })
            })
            .collect();
        drop(store);
        out.sort_by(|a, b| b.score.total_cmp(&a.score).then(a.ordinal.cmp(&b.ordinal)));
        out
    }
}

/// Per-meta-run view of a [`MetaTuning`]: an [`EvalBackend`] over the meta
/// search space whose objective is **−P** (the tuning context minimizes;
/// the leaderboard reports the positive score).
pub struct MetaBackend<'a> {
    inner: &'a MetaTuning,
}

impl EvalBackend for MetaBackend<'_> {
    fn space(&self) -> &Arc<SearchSpace> {
        self.inner.space()
    }

    fn id(&self) -> String {
        self.inner.space.name.clone()
    }

    fn eval_cost_s(&self, _i: u32) -> f64 {
        self.inner.meta_eval_cost_s()
    }

    fn evaluate_batch(&mut self, configs: &[u32]) -> Vec<Option<f64>> {
        self.inner
            .evaluate_all(configs, self.inner.runs)
            .into_iter()
            .map(|s| Some(-s.score))
            .collect()
    }
}

impl BackendSource for MetaTuning {
    fn backend(&self) -> Box<dyn EvalBackend + '_> {
        Box::new(MetaBackend { inner: self })
    }

    fn space_id(&self) -> String {
        self.space.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CacheKey, CacheRegistry};

    fn tiny() -> MetaTuning {
        let reg = CacheRegistry::global();
        let entries = vec![reg.entry(CacheKey::parse("convolution@A4000").unwrap())];
        // Pin everything but `elites`: a 4-point meta space keeps the
        // tests fast.
        let base = OptimizerSpec::parse(
            "ga:population_size=8,tournament_k=2,crossover_rate=0.8,mutation_rate_factor=0.8",
        )
        .unwrap();
        MetaTuning::new(base, entries, 2, 7, Some(2)).unwrap()
    }

    #[test]
    fn ordinal_zero_inherits_the_sweep_seed() {
        assert_eq!(meta_seed(42, 0), 42);
        assert_ne!(meta_seed(42, 1), 42);
        assert_ne!(meta_seed(42, 1), meta_seed(42, 2));
    }

    #[test]
    fn meta_evaluations_are_memoized_and_deterministic() {
        let mt = tiny();
        assert_eq!(mt.space().len(), 4);
        let a = mt.evaluate_all(&[0, 1, 2, 3], 2);
        assert_eq!(mt.memo_hits(), 0);
        assert_eq!(mt.evaluations(), 4);
        let b = mt.evaluate_all(&[0, 1, 2, 3], 2);
        assert_eq!(a, b);
        assert_eq!(mt.memo_hits(), 4, "second pass must hit the memo");
        // A lower run count is answered from the stored curve prefix...
        let c = mt.evaluate_all(&[0], 1);
        assert_eq!(mt.memo_hits(), 5);
        assert_eq!(mt.evaluations(), 4, "prefix queries run no jobs");
        // ...and equals a from-scratch lower-rung computation bit-for-bit.
        assert_eq!(c[0], tiny().evaluate_all(&[0], 1)[0]);
        // Rung escalation appends only the new seed indices (one more
        // expansion, not a redo) and still equals a from-scratch grid.
        let d = mt.evaluate_all(&[0], 3);
        assert_eq!(mt.evaluations(), 5);
        assert_eq!(d[0], tiny().evaluate_all(&[0], 3)[0]);
        // The leaderboard keeps each ordinal at its highest run count.
        let lb = mt.leaderboard();
        assert_eq!(lb.len(), 4);
        assert_eq!(lb.iter().find(|r| r.ordinal == 0).unwrap().runs, 3);
        assert!(lb.windows(2).all(|w| w[0].score >= w[1].score));
    }

    #[test]
    fn backend_objective_is_negated_score() {
        let mt = tiny();
        let direct = mt.evaluate_all(&[1], mt.runs())[0].score;
        let mut backend = mt.backend();
        let via_backend = backend.evaluate_one(1).unwrap();
        assert_eq!(via_backend, -direct);
        assert!(backend.eval_cost_s(0) > 0.0);
        assert_eq!(mt.space_id(), "hypertune:ga");
    }

    #[test]
    fn expanded_specs_carry_pins_and_decoded_overrides() {
        let mt = tiny();
        let spec = mt.spec_for(0);
        let shown = spec.to_string();
        assert!(shown.starts_with("ga:population_size=8"), "{}", shown);
        assert!(shown.contains("elites="), "{}", shown);
        // The expanded spec must itself be valid configuration.
        let _ = spec.build();
    }

    #[test]
    fn genome_bases_are_rejected() {
        let reg = CacheRegistry::global();
        let entries = vec![reg.entry(CacheKey::parse("convolution@A4000").unwrap())];
        let g = OptimizerSpec::genome(crate::llamea::Genome::hybrid_vndx_like());
        assert!(MetaTuning::new(g, entries.clone(), 2, 1, None).is_err());
        let ok = OptimizerSpec::named("sa");
        assert!(MetaTuning::new(ok, Vec::new(), 2, 1, None).is_err(), "no spaces");
    }
}
