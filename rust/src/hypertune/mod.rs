//! Hypertune: meta-tuning — the repo's optimizers tuning the repo's
//! optimizers through their own machinery.
//!
//! The source paper hand-tunes GA/SA hyperparameters for seven days of
//! compute before comparing against its generated optimizers; its
//! companion work ("Tuning the Tuner", Willemsen et al. 2025) argues that
//! optimizer hyperparameters are themselves a tuning problem. This module
//! closes the loop with the two seams PRs 1–2 built:
//!
//! - a hyperparameter configuration is a point in an ordinary
//!   [`SearchSpace`](crate::searchspace::SearchSpace) built from the
//!   typed [`HyperParamDomain`](crate::optimizers::HyperParamDomain)s
//!   every registry optimizer declares ([`space`]);
//! - the cost of that point is the aggregate methodology score of a grid
//!   of seeded tuning runs, streamed as one [`TuningJob`] batch through
//!   the sweep's shared bounded executor (rung escalations at higher
//!   priority) and collapsed by
//!   [`aggregate`](crate::methodology::aggregate) ([`backend`]);
//! - meta-search is exhaustive grid, seeded random, successive halving
//!   with seeds-per-rung escalation, or *any registry optimizer* driving
//!   a plain `TuningContext` over the [`MetaBackend`] ([`strategy`]).
//!
//! ## Determinism contract
//!
//! Sweep output — leaderboard, rung trace, and the `sweep --out` JSON —
//! is byte-identical for any scheduler width. Inner tuning seeds derive
//! from [`meta_seed`] (sweep seed × meta-config *ordinal*) and the job's
//! grid coordinates, never from execution order; ranking ties break by
//! ordinal; and [`meta_seed`]`(s, 0) == s`, so a grid-of-one sweep (every
//! key pinned on the base spec) issues bit-for-bit the jobs `coordinate`
//! issues for the same spec. All three properties are pinned by
//! `rust/tests/integration_hypertune.rs`.
//!
//! [`TuningJob`]: crate::coordinator::TuningJob

pub mod backend;
pub mod space;
pub mod strategy;

pub use backend::{meta_seed, MetaBackend, MetaResult, MetaScore, MetaTuning, SweepProgress};
pub use space::{decode, meta_space};
pub use strategy::{
    halving_keep, leaderboard_table, successive_halving, sweep, sweep_json, sweep_partial_json,
    MetaStrategy, Rung, SweepOutcome,
};
