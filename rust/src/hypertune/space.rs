//! Meta search spaces: hyperparameter domains as an ordinary
//! [`SearchSpace`].
//!
//! A hyperparameter configuration is a point in a small constraint-free
//! space whose dimensions are the optimizer's [`HyperParamDomain`]s, built
//! through the same [`SearchSpace`] machinery the kernel spaces use — so
//! every registry optimizer (neighbors, repair, random sampling all
//! included) can search it unchanged.
//!
//! Keys already overridden on the base [`OptimizerSpec`] are *pinned*:
//! they are excluded from the meta space and carried verbatim on every
//! expanded spec, which is how a sweep is narrowed to a subset of knobs —
//! and how a grid-of-one (everything pinned) degenerates to exactly one
//! meta-configuration, the seam the golden `coordinate`-equivalence test
//! exercises.
//!
//! [`OptimizerSpec`]: crate::optimizers::OptimizerSpec

use crate::optimizers::HyperParamDomain;
use crate::searchspace::{Param, ParamSet, SearchSpace};

/// Dimension name of the sentinel parameter used when no unpinned domains
/// remain (all keys pinned, or a knob-less optimizer): the meta space then
/// holds exactly one configuration, and [`decode`] skips this dimension.
pub const SENTINEL: &str = "__defaults__";

/// Build the meta search space of one optimizer: one float dimension per
/// unpinned hyperparameter domain, no constraints, named
/// `hypertune:<label>`.
pub fn meta_space(label: &str, domains: &[HyperParamDomain], pinned: &[String]) -> SearchSpace {
    let mut params: Vec<Param> = domains
        .iter()
        .filter(|d| !pinned.iter().any(|p| p == d.key))
        .map(|d| Param::floats(d.key, d.values))
        .collect();
    if params.is_empty() {
        params.push(Param::fixed(SENTINEL, 0));
    }
    SearchSpace::build_parsed(&format!("hypertune:{}", label), ParamSet::new(params), Vec::new())
}

/// Decode meta configuration `i` into `(key, value)` hyperparameter
/// overrides, in dimension (= declaration) order.
pub fn decode(space: &SearchSpace, i: u32) -> Vec<(String, f64)> {
    space
        .config(i)
        .iter()
        .enumerate()
        .filter(|(d, _)| space.params.params[*d].name != SENTINEL)
        .map(|(d, &vi)| (space.params.params[d].name.clone(), space.params.value_f64(d, vi)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::by_name;

    #[test]
    fn meta_space_is_the_domain_product() {
        let ga = by_name("ga").unwrap();
        let domains = ga.hyperparam_domains();
        let space = meta_space("ga", domains, &[]);
        let expected: usize = domains.iter().map(|d| d.values.len()).product();
        assert_eq!(space.len(), expected);
        assert_eq!(space.dims(), domains.len());
        assert_eq!(space.name, "hypertune:ga");
        // Every config decodes to one override per dimension, with values
        // drawn from the declared domains.
        let overrides = decode(&space, 0);
        assert_eq!(overrides.len(), domains.len());
        for ((k, v), d) in overrides.iter().zip(domains) {
            assert_eq!(k, d.key);
            assert!(d.contains(*v));
        }
    }

    #[test]
    fn pinning_removes_dimensions() {
        let ga = by_name("ga").unwrap();
        let domains = ga.hyperparam_domains();
        let space = meta_space("ga", domains, &["population_size".to_string()]);
        assert_eq!(space.dims(), domains.len() - 1);
        assert!(decode(&space, 0).iter().all(|(k, _)| k != "population_size"));
    }

    #[test]
    fn fully_pinned_space_is_a_single_config() {
        let ga = by_name("ga").unwrap();
        let pinned: Vec<String> =
            ga.hyperparam_domains().iter().map(|d| d.key.to_string()).collect();
        let space = meta_space("ga", ga.hyperparam_domains(), &pinned);
        assert_eq!(space.len(), 1);
        assert!(decode(&space, 0).is_empty(), "sentinel must not decode");
        // A knob-less optimizer degenerates the same way.
        let none = meta_space("random", &[], &[]);
        assert_eq!(none.len(), 1);
        assert!(decode(&none, 0).is_empty());
    }
}
