//! Tuning substrate: the pre-explored evaluation caches ("simulation mode")
//! and the budgeted evaluation context handed to optimization algorithms.

pub mod cache;
pub mod evaluator;

pub use cache::{build_all_caches, build_caches_for, Cache};
pub use evaluator::TuningContext;
