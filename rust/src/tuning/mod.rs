//! Tuning substrate: pluggable evaluation backends behind the budgeted
//! evaluation context handed to optimization algorithms.
//!
//! - [`backend`]: the [`EvalBackend`] trait (batch evaluation + per-config
//!   cost accounting + a space handle) and [`CachedBackend`], the
//!   simulation-mode implementation over a pre-explored [`Cache`].
//!   [`BackendSource`] mints per-run backends for the job graph.
//! - [`cache`]: the pre-explored evaluation caches ("simulation mode").
//! - [`evaluator`]: [`TuningContext`], the run-level layer (dedup, wall
//!   clock, trajectory, budget) every optimizer runs against, with both
//!   single-point (`evaluate`) and ask/tell batch (`evaluate_batch`)
//!   submission paths.

pub mod backend;
pub mod cache;
pub mod evaluator;

pub use backend::{BackendSource, CachedBackend, EvalBackend};
pub use cache::{build_all_caches, build_caches_for, Cache};
pub use evaluator::TuningContext;
