//! Pre-exhaustively-explored search spaces ("simulation mode").
//!
//! The paper accelerates optimizer evaluation by replaying cachefiles of
//! exhaustively benchmarked search spaces instead of recompiling/running
//! kernels. `Cache` is our equivalent: the performance model is evaluated
//! once for every valid configuration of a (kernel, GPU) pair; optimizers
//! then see only (config -> noisy runtime) lookups plus simulated
//! compile/run wall-clock accounting — exactly the interface the real
//! system has.

use std::sync::Arc;

use crate::kernels::gpu::GpuSpec;
use crate::kernels::{model_for, space_salt, KernelModel};
use crate::persist::arena::Arena;
use crate::searchspace::{Application, SearchSpace};
use crate::util::rng::{hash_config, hash_normal};

/// Exhaustive evaluation of one (application, GPU) search space.
pub struct Cache {
    pub space: Arc<SearchSpace>,
    pub app: Application,
    pub gpu: &'static GpuSpec,
    /// Mean runtime per valid config, ms; +inf marks hidden-failure configs.
    /// An [`Arena`] so a warm start (`crate::persist`) can borrow it
    /// zero-copy from an mmap'd store file; fresh builds own a `Vec`.
    pub mean_ms: Arena<f32>,
    /// Simulated compile time per config, seconds (arena, as above).
    pub compile_s: Arena<f32>,
    /// Global optimum of `mean_ms` (ms).
    pub optimum_ms: f64,
    /// Median of the successful configs (ms).
    pub median_ms: f64,
    /// Mean evaluation cost (compile + benchmark runs) over the space, s —
    /// the expected cost of one random-search step.
    pub mean_eval_cost_s: f64,
    /// Salt keying the deterministic noise streams of this space.
    pub salt: u64,
}

/// Number of benchmark repetitions Kernel Tuner performs per configuration.
pub const RUNS_PER_EVAL: u32 = 7;
/// Relative measurement noise per benchmark run (lognormal sigma).
pub const MEASUREMENT_SIGMA: f64 = 0.04;
/// Wall-clock cost charged for a failed (crashing) configuration, seconds.
pub const FAILURE_COST_S: f64 = 1.0;

impl Cache {
    /// Build by exhaustively evaluating the model over the space.
    pub fn build(app: Application, gpu: &'static GpuSpec) -> Cache {
        let space = Arc::new(app.build_space());
        Self::build_with_space(app, gpu, space)
    }

    /// Build against an existing (shared) space — the space enumeration is
    /// the expensive part for hotspot, so callers batch-share it.
    ///
    /// Model evaluation is embarrassingly parallel (each entry is a pure
    /// function of its config), so it is chunked across the process
    /// default width; chunk outputs concatenate in index order, keeping
    /// the cache byte-identical for any `--threads`.
    pub fn build_with_space(
        app: Application,
        gpu: &'static GpuSpec,
        space: Arc<SearchSpace>,
    ) -> Cache {
        Self::build_with_space_width(app, gpu, space, crate::util::parallel::default_width())
    }

    /// [`Self::build_with_space`] with an explicit worker count (the
    /// determinism tests compare width 1 against wide builds).
    pub fn build_with_space_width(
        app: Application,
        gpu: &'static GpuSpec,
        space: Arc<SearchSpace>,
        width: usize,
    ) -> Cache {
        let model: Box<dyn KernelModel> = model_for(app, &space.params);
        let salt = space_salt(app, gpu);
        let n = space.len();
        let model_ref: &dyn KernelModel = &*model;
        let space_ref: &SearchSpace = &space;
        let chunks = crate::util::parallel::map_chunks_width(n, 4096, width, |range| {
            let mut mean_ms = Vec::with_capacity(range.len());
            let mut compile_s = Vec::with_capacity(range.len());
            let mut vals = Vec::with_capacity(space_ref.dims());
            for i in range {
                let cfg = space_ref.config(i as u32);
                space_ref.values_f64_into(i as u32, &mut vals);
                let t = model_ref.runtime_ms(&vals, gpu, salt);
                mean_ms.push(t.map(|t| t as f32).unwrap_or(f32::INFINITY));
                // Compile time: a deterministic lognormal spread around
                // the device mean, keyed only by the config hash. It does
                // NOT model code size — no parameter (unrolling included)
                // shifts the distribution; only the identity of the
                // config selects the draw.
                let h = hash_config(salt ^ 0xC0817E, cfg);
                let z = hash_normal(h);
                compile_s.push((gpu.compile_time_s * (0.35 * z).exp()) as f32);
            }
            (mean_ms, compile_s)
        });
        let mut mean_ms = Vec::with_capacity(n);
        let mut compile_s = Vec::with_capacity(n);
        for (mm, cs) in chunks {
            mean_ms.extend_from_slice(&mm);
            compile_s.extend_from_slice(&cs);
        }

        let (optimum_ms, median_ms, mean_eval_cost_s) = Self::summary_stats(&mean_ms, &compile_s)
            .unwrap_or_else(|| panic!("no runnable configuration in {}", space.name));

        Cache {
            space,
            app,
            gpu,
            mean_ms: mean_ms.into(),
            compile_s: compile_s.into(),
            optimum_ms,
            median_ms,
            mean_eval_cost_s,
            salt,
        }
    }

    /// Summary statistics over the raw arenas:
    /// `(optimum_ms, median_ms, mean_eval_cost_s)`, or `None` when no
    /// config is runnable. This is the single definition shared by fresh
    /// builds, measured caches and the persistent store's load-time
    /// integrity check (`crate::persist` recomputes these from the loaded
    /// arenas and asserts equality with the stored values — any
    /// disagreement rejects the file).
    pub fn summary_stats(mean_ms: &[f32], compile_s: &[f32]) -> Option<(f64, f64, f64)> {
        assert_eq!(mean_ms.len(), compile_s.len());
        let mut ok: Vec<f64> = mean_ms
            .iter()
            .filter(|t| t.is_finite())
            .map(|&t| t as f64)
            .collect();
        if ok.is_empty() {
            return None;
        }
        ok.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let optimum_ms = ok[0];
        let median_ms = ok[ok.len() / 2];
        let n = mean_ms.len();
        let mut total = 0.0;
        for i in 0..n {
            total += compile_s[i] as f64
                + if mean_ms[i].is_finite() {
                    RUNS_PER_EVAL as f64 * mean_ms[i] as f64 * 1e-3
                } else {
                    FAILURE_COST_S
                };
        }
        Some((optimum_ms, median_ms, total / n as f64))
    }

    /// Assemble a cache from deserialized arenas (`crate::persist`). The
    /// summary statistics are recomputed here — never trusted from disk —
    /// so the caller can compare them against the stored triple.
    pub(crate) fn from_arenas(
        app: Application,
        gpu: &'static GpuSpec,
        space: Arc<SearchSpace>,
        mean_ms: Arena<f32>,
        compile_s: Arena<f32>,
        salt: u64,
    ) -> Result<Cache, String> {
        if mean_ms.len() != space.len() || compile_s.len() != space.len() {
            return Err(format!(
                "arena lengths {}/{} do not match space size {}",
                mean_ms.len(),
                compile_s.len(),
                space.len()
            ));
        }
        let (optimum_ms, median_ms, mean_eval_cost_s) =
            Self::summary_stats(&mean_ms, &compile_s)
                .ok_or_else(|| "no runnable configuration".to_string())?;
        Ok(Cache {
            space,
            app,
            gpu,
            mean_ms,
            compile_s,
            optimum_ms,
            median_ms,
            mean_eval_cost_s,
            salt,
        })
    }

    /// Assemble a cache from *real* measurements (the PJRT measured-tuning
    /// path, `crate::runtime::measured`): entries are wall-clock means; the
    /// application tag is taken from the space name's prefix when it
    /// matches a known application, defaulting to GEMM.
    pub fn from_measured(
        space: Arc<SearchSpace>,
        mean_ms: Vec<f32>,
        compile_s: Vec<f32>,
        salt: u64,
    ) -> Cache {
        assert_eq!(mean_ms.len(), space.len());
        assert_eq!(compile_s.len(), space.len());
        let app = Application::ALL
            .iter()
            .copied()
            .find(|a| space.name.starts_with(a.name()))
            .unwrap_or(Application::Gemm);
        let (optimum_ms, median_ms, mean_eval_cost_s) =
            Self::summary_stats(&mean_ms, &compile_s).expect("no successful measurement");
        Cache {
            space,
            app,
            gpu: &crate::kernels::gpu::CPU_HOST,
            mean_ms: mean_ms.into(),
            compile_s: compile_s.into(),
            optimum_ms,
            median_ms,
            mean_eval_cost_s,
            salt,
        }
    }

    /// Human-readable space identifier, e.g. `gemm@A100`.
    pub fn id(&self) -> String {
        format!("{}@{}", self.app.name(), self.gpu.name)
    }

    pub fn len(&self) -> usize {
        self.mean_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean_ms.is_empty()
    }

    /// True mean runtime of config `i` (ms), or None for failure configs.
    #[inline]
    pub fn true_mean_ms(&self, i: u32) -> Option<f64> {
        let t = self.mean_ms[i as usize];
        t.is_finite().then_some(t as f64)
    }

    /// One noisy benchmark observation of config `i` (ms). `draw` indexes
    /// the observation so repeated measurements differ deterministically.
    #[inline]
    pub fn observe_ms(&self, i: u32, draw: u64) -> Option<f64> {
        let t = self.mean_ms[i as usize];
        if !t.is_finite() {
            return None;
        }
        let h = hash_config(self.salt ^ draw.wrapping_mul(0x9E3779B97F4A7C15), self.space.config(i));
        Some(t as f64 * (MEASUREMENT_SIGMA * hash_normal(h)).exp())
    }

    /// Mean of `runs` consecutive noisy observations of config `i`
    /// starting at draw ordinal `base` — bit-identical to averaging
    /// [`Self::observe_ms`] over `base..base+runs` (same per-draw values,
    /// same accumulation order), with the config slice fetch and the
    /// finiteness check hoisted out of the loop. This is the simulated
    /// evaluation inner loop ([`super::backend::CachedBackend`]).
    #[inline]
    pub fn observe_mean_ms(&self, i: u32, base: u64, runs: u32) -> Option<f64> {
        let t = self.mean_ms[i as usize];
        if !t.is_finite() {
            return None;
        }
        let cfg = self.space.config(i);
        let mut sum = 0.0;
        for r in 0..runs as u64 {
            let h = hash_config(self.salt ^ (base + r).wrapping_mul(0x9E3779B97F4A7C15), cfg);
            sum += t as f64 * (MEASUREMENT_SIGMA * hash_normal(h)).exp();
        }
        Some(sum / runs as f64)
    }

    /// Simulated wall-clock cost of evaluating config `i` once (compile +
    /// benchmark repetitions), seconds.
    #[inline]
    pub fn eval_cost_s(&self, i: u32) -> f64 {
        let compile = self.compile_s[i as usize] as f64;
        let t = self.mean_ms[i as usize];
        if t.is_finite() {
            compile + RUNS_PER_EVAL as f64 * t as f64 * 1e-3
        } else {
            compile + FAILURE_COST_S
        }
    }

    /// Sorted successful runtimes (ascending, ms) — the objective-value
    /// distribution used by the calculated random-search baseline.
    pub fn sorted_times(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self
            .mean_ms
            .iter()
            .filter(|t| t.is_finite())
            .map(|&t| t as f64)
            .collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }
}

/// Build the full 24-cache evaluation set (4 applications x 6 GPUs),
/// sharing each application's space across its 6 GPU caches.
pub fn build_all_caches() -> Vec<Cache> {
    use crate::kernels::gpu::ALL_GPUS;
    let mut out = Vec::with_capacity(24);
    for app in Application::ALL {
        let space = Arc::new(app.build_space());
        for gpu in ALL_GPUS.iter() {
            out.push(Cache::build_with_space(app, gpu, Arc::clone(&space)));
        }
    }
    out
}

/// Caches for the training set (generation phase) or test set.
pub fn build_caches_for(gpu_names: &[&str]) -> Vec<Cache> {
    use crate::kernels::gpu::GpuSpec;
    let mut out = Vec::new();
    for app in Application::ALL {
        let space = Arc::new(app.build_space());
        for name in gpu_names {
            let gpu = GpuSpec::by_name(name).expect("unknown GPU");
            out.push(Cache::build_with_space(app, gpu, Arc::clone(&space)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;

    fn small_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    #[test]
    fn cache_covers_space() {
        let c = small_cache();
        assert_eq!(c.len(), c.space.len());
        assert!(c.optimum_ms > 0.0);
        assert!(c.median_ms > c.optimum_ms);
    }

    #[test]
    fn observations_are_noisy_but_deterministic() {
        let c = small_cache();
        let i = 10u32;
        if let Some(t) = c.true_mean_ms(i) {
            let a = c.observe_ms(i, 0).unwrap();
            let b = c.observe_ms(i, 1).unwrap();
            assert_ne!(a, b);
            assert_eq!(a, c.observe_ms(i, 0).unwrap());
            assert!((a / t - 1.0).abs() < 0.5);
        }
    }

    #[test]
    fn eval_cost_includes_compile_and_runs() {
        let c = small_cache();
        for i in 0..20u32 {
            let cost = c.eval_cost_s(i);
            assert!(cost > 0.5, "cost {}", cost); // at least compile time
        }
        assert!(c.mean_eval_cost_s > 0.5);
    }

    #[test]
    fn failures_present_but_rare() {
        let c = small_cache();
        let failures = c.mean_ms.iter().filter(|t| !t.is_finite()).count();
        let rate = failures as f64 / c.len() as f64;
        assert!(rate > 0.0 && rate < 0.12, "failure rate {}", rate);
    }

    #[test]
    fn observe_mean_matches_per_draw_loop() {
        let c = small_cache();
        for i in 0..40u32 {
            for base in [0u64, 8, 1024] {
                let fused = c.observe_mean_ms(i, base, RUNS_PER_EVAL);
                let loop_mean = c.true_mean_ms(i).map(|_| {
                    let mut sum = 0.0;
                    for r in 0..RUNS_PER_EVAL as u64 {
                        sum += c.observe_ms(i, base + r).unwrap();
                    }
                    sum / RUNS_PER_EVAL as f64
                });
                assert_eq!(fused, loop_mean, "config {} base {}", i, base);
            }
        }
    }

    #[test]
    fn parallel_cache_build_identical_to_serial() {
        let app = Application::Convolution;
        let gpu = GpuSpec::by_name("A4000").unwrap();
        let space = std::sync::Arc::new(app.build_space());
        let serial = Cache::build_with_space_width(app, gpu, std::sync::Arc::clone(&space), 1);
        let wide = Cache::build_with_space_width(app, gpu, std::sync::Arc::clone(&space), 8);
        assert_eq!(serial.mean_ms, wide.mean_ms);
        assert_eq!(serial.compile_s, wide.compile_s);
        assert_eq!(serial.optimum_ms, wide.optimum_ms);
        assert_eq!(serial.median_ms, wide.median_ms);
        assert_eq!(serial.mean_eval_cost_s, wide.mean_eval_cost_s);
    }

    #[test]
    fn sorted_times_ascending() {
        let c = small_cache();
        let s = c.sorted_times();
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(s[0], c.optimum_ms);
    }
}
