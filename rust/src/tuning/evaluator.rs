//! The evaluation context handed to optimization algorithms.
//!
//! `TuningContext` plays the role of Kernel Tuner's runner + cost function:
//! it owns the simulated wall clock (compile + benchmark time per unique
//! configuration, near-zero for cache hits), deduplicates repeated
//! evaluations, tracks the best-found trajectory over time (the input to
//! the methodology's performance curves), and exposes the time budget that
//! generated algorithms consult via `budget_spent_fraction` — mirroring
//! `f.budget_spent_fraction` in the paper's Algorithm 1.

use std::collections::HashMap;

use super::cache::{Cache, RUNS_PER_EVAL};
use crate::searchspace::space::FxBuildHasher;
use crate::searchspace::SearchSpace;
use crate::util::rng::Rng;

/// Wall-clock charged for a strategy step that hits the evaluation cache
/// (config already measured): bookkeeping only, but non-zero so degenerate
/// strategies cannot spin forever inside a fixed budget.
pub const CACHED_EVAL_COST_S: f64 = 0.05;

/// Hard safety cap on evaluate() calls per run (simulation guard).
pub const MAX_EVAL_CALLS: u64 = 2_000_000;

/// One tuning run's evaluation state.
pub struct TuningContext<'a> {
    pub cache: &'a Cache,
    pub rng: Rng,
    clock_s: f64,
    budget_s: f64,
    eval_calls: u64,
    unique_evals: u64,
    seen: HashMap<u32, Option<f64>, FxBuildHasher>,
    best_ms: f64,
    best_idx: Option<u32>,
    /// (wall-clock seconds, best-so-far ms) at each improvement.
    pub trajectory: Vec<(f64, f64)>,
}

impl<'a> TuningContext<'a> {
    pub fn new(cache: &'a Cache, budget_s: f64, seed: u64) -> TuningContext<'a> {
        TuningContext {
            cache,
            rng: Rng::new(seed),
            clock_s: 0.0,
            budget_s,
            eval_calls: 0,
            unique_evals: 0,
            seen: HashMap::with_hasher(FxBuildHasher::default()),
            best_ms: f64::INFINITY,
            best_idx: None,
            trajectory: Vec::new(),
        }
    }

    /// The search space (borrowed at the cache's lifetime, so callers can
    /// hold it while mutably using `self.rng` / `evaluate`).
    #[inline]
    pub fn space(&self) -> &'a SearchSpace {
        &self.cache.space
    }

    /// Evaluate configuration `i`; returns the observed mean runtime in ms
    /// (`None` for crashing configurations). Charges simulated wall-clock:
    /// full compile+benchmark cost for new configurations, a bookkeeping
    /// epsilon for repeats.
    pub fn evaluate(&mut self, i: u32) -> Option<f64> {
        self.eval_calls += 1;
        if let Some(&v) = self.seen.get(&i) {
            self.clock_s += CACHED_EVAL_COST_S;
            return v;
        }
        self.clock_s += self.cache.eval_cost_s(i);
        self.unique_evals += 1;
        // Observed value: mean over the benchmark repetitions.
        let value = self.cache.true_mean_ms(i).map(|_| {
            let mut sum = 0.0;
            let base = self.unique_evals.wrapping_mul(RUNS_PER_EVAL as u64 + 1);
            for r in 0..RUNS_PER_EVAL as u64 {
                sum += self.cache.observe_ms(i, base + r).unwrap();
            }
            sum / RUNS_PER_EVAL as f64
        });
        self.seen.insert(i, value);
        if let Some(v) = value {
            if v < self.best_ms {
                self.best_ms = v;
                self.best_idx = Some(i);
                self.trajectory.push((self.clock_s, v));
            }
        }
        value
    }

    /// True when the time budget (or the call-count safety cap) is spent.
    #[inline]
    pub fn budget_exhausted(&self) -> bool {
        self.clock_s >= self.budget_s || self.eval_calls >= MAX_EVAL_CALLS
    }

    /// Fraction of the time budget consumed, clamped to [0, 1]. A
    /// non-positive budget reports 1.0 (fully spent) rather than NaN —
    /// generated-optimizer schedules branch on this value, and NaN would
    /// silently disable every `fraction < x` phase switch.
    #[inline]
    pub fn budget_spent_fraction(&self) -> f64 {
        if self.budget_s <= 0.0 {
            return 1.0;
        }
        (self.clock_s / self.budget_s).min(1.0)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.clock_s
    }

    pub fn budget_s(&self) -> f64 {
        self.budget_s
    }

    /// Best configuration found so far with its observed runtime.
    pub fn best(&self) -> Option<(u32, f64)> {
        self.best_idx.map(|i| (i, self.best_ms))
    }

    pub fn unique_evals(&self) -> u64 {
        self.unique_evals
    }

    pub fn eval_calls(&self) -> u64 {
        self.eval_calls
    }

    /// Whether `i` has been evaluated already (tabu-style checks).
    pub fn already_evaluated(&self, i: u32) -> bool {
        self.seen.contains_key(&i)
    }

    /// Observed value of an already-evaluated config (no time charged).
    pub fn peek(&self, i: u32) -> Option<Option<f64>> {
        self.seen.get(&i).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;

    fn ctx_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    #[test]
    fn clock_advances_and_dedup_is_cheap() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 1e9, 1);
        let t0 = ctx.elapsed_s();
        ctx.evaluate(0);
        let t1 = ctx.elapsed_s();
        assert!(t1 > t0 + 0.1); // compile time at least
        ctx.evaluate(0);
        let t2 = ctx.elapsed_s();
        assert!(t2 - t1 < CACHED_EVAL_COST_S + 1e-9); // cached
        assert_eq!(ctx.unique_evals(), 1);
        assert_eq!(ctx.eval_calls(), 2);
    }

    #[test]
    fn best_tracks_improvements_only() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 1e9, 2);
        for i in 0..100u32 {
            ctx.evaluate(i);
        }
        let (best_i, best_v) = ctx.best().unwrap();
        // Trajectory is strictly decreasing in value, increasing in time.
        let tr = &ctx.trajectory;
        assert!(tr.windows(2).all(|w| w[1].1 < w[0].1 && w[1].0 >= w[0].0));
        assert_eq!(tr.last().unwrap().1, best_v);
        assert!(ctx.peek(best_i).unwrap().unwrap() == best_v);
    }

    #[test]
    fn budget_exhaustion() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 10.0, 3);
        let mut n = 0;
        while !ctx.budget_exhausted() {
            ctx.evaluate(n);
            n += 1;
        }
        assert!(ctx.elapsed_s() >= 10.0);
        assert!(ctx.budget_spent_fraction() >= 1.0 - 1e-12);
        assert!(n < 100, "budget should bound evals, got {}", n);
    }

    #[test]
    fn zero_budget_reports_fully_spent_not_nan() {
        let cache = ctx_cache();
        let ctx = TuningContext::new(&cache, 0.0, 4);
        assert_eq!(ctx.budget_spent_fraction(), 1.0);
        assert!(ctx.budget_exhausted());
        let neg = TuningContext::new(&cache, -5.0, 4);
        assert_eq!(neg.budget_spent_fraction(), 1.0);
    }

    #[test]
    fn observed_values_reproducible_per_seed() {
        let cache = ctx_cache();
        let a = {
            let mut ctx = TuningContext::new(&cache, 1e9, 7);
            (0..20u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        let b = {
            let mut ctx = TuningContext::new(&cache, 1e9, 7);
            (0..20u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        assert_eq!(a, b);
    }
}
