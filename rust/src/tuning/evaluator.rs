//! The evaluation context handed to optimization algorithms.
//!
//! `TuningContext` plays the role of Kernel Tuner's runner + cost function:
//! it owns the wall clock (compile + benchmark time per unique
//! configuration, near-zero for cache hits), deduplicates repeated
//! evaluations, tracks the best-found trajectory over time (the input to
//! the methodology's performance curves), and exposes the time budget that
//! generated algorithms consult via `budget_spent_fraction` — mirroring
//! `f.budget_spent_fraction` in the paper's Algorithm 1.
//!
//! Objective values come from a pluggable [`EvalBackend`]
//! (`super::backend`): a replayed [`Cache`] in simulation mode, or a
//! measured backend timing real program variants. The context adds the
//! run-level semantics on top — so every optimizer works unchanged against
//! either — and offers two submission paths:
//!
//! - [`TuningContext::evaluate`]: one configuration, charged immediately
//!   (the classic sequential path).
//! - [`TuningContext::evaluate_batch`]: a whole batch (an ask/tell
//!   generation) forwarded to the backend in one call, with per-config
//!   dedup, budget cuts and trajectory stamps applied in submission order
//!   so a batch is observationally identical to the same configurations
//!   submitted one at a time by a caller that checks `budget_exhausted`
//!   between evaluations.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::Arc;

use super::backend::{CachedBackend, EvalBackend};
use super::cache::Cache;
use crate::searchspace::space::FxBuildHasher;
use crate::searchspace::SearchSpace;
use crate::util::cancel::CancelToken;
use crate::util::rng::Rng;

/// Wall-clock charged for a strategy step that hits the evaluation cache
/// (config already measured): bookkeeping only, but non-zero so degenerate
/// strategies cannot spin forever inside a fixed budget.
pub const CACHED_EVAL_COST_S: f64 = 0.05;

/// Hard safety cap on evaluate() calls per run (simulation guard).
pub const MAX_EVAL_CALLS: u64 = 2_000_000;

/// The backend a context drives: an owned cached backend (the common,
/// statically-dispatched simulation path) or any caller-provided backend.
enum ContextBackend<'a> {
    Cached(CachedBackend<'a>),
    External(&'a mut (dyn EvalBackend + 'a)),
}

impl ContextBackend<'_> {
    fn as_dyn(&mut self) -> &mut dyn EvalBackend {
        match self {
            ContextBackend::Cached(b) => b,
            ContextBackend::External(b) => &mut **b,
        }
    }

    fn as_dyn_ref(&self) -> &dyn EvalBackend {
        match self {
            ContextBackend::Cached(b) => b,
            ContextBackend::External(b) => &**b,
        }
    }
}

/// Per-config decision of a batch plan (see [`TuningContext::evaluate_batch`]).
#[derive(Clone, Copy)]
enum Step {
    /// Budget/call-cap exhausted before this config: not evaluated.
    Skip,
    /// Already evaluated (earlier in the run or earlier in this batch).
    Repeat,
    /// Fresh evaluation; payload is the slot in the backend batch.
    Fresh(usize),
}

/// One tuning run's evaluation state.
pub struct TuningContext<'a> {
    backend: ContextBackend<'a>,
    space: Arc<SearchSpace>,
    pub rng: Rng,
    clock_s: f64,
    budget_s: f64,
    eval_calls: u64,
    unique_evals: u64,
    seen: HashMap<u32, Option<f64>, FxBuildHasher>,
    best_ms: f64,
    best_idx: Option<u32>,
    /// (wall-clock seconds, best-so-far ms) at each improvement.
    pub trajectory: Vec<(f64, f64)>,
    batch_calls: u64,
    batched_evals: u64,
    largest_batch: usize,
    /// Cooperative cancellation: when any attached token fires, the budget
    /// reads as exhausted so the optimizer winds down between evaluations.
    /// Several tokens can coexist (the executor's batch token plus a
    /// per-arm racing token, say); observing *any* fired one cancels the
    /// run. Empty = not cancellable.
    cancel: Vec<CancelToken>,
    /// Whether a budget check ever *observed* the fired token. A run that
    /// completes without observing it behaved bit-identically to an
    /// uncancelled run; a run that observed it was cut short and its
    /// outputs must be discarded (see [`Self::cancellation_observed`]).
    cancel_observed: Cell<bool>,
}

impl<'a> TuningContext<'a> {
    /// Context over a pre-explored cache (simulation mode).
    pub fn new(cache: &'a Cache, budget_s: f64, seed: u64) -> TuningContext<'a> {
        Self::build(ContextBackend::Cached(CachedBackend::new(cache)), budget_s, seed)
    }

    /// Context over any evaluation backend (the general path: measured
    /// backends, test doubles, future remote evaluators).
    pub fn with_backend(
        backend: &'a mut (dyn EvalBackend + 'a),
        budget_s: f64,
        seed: u64,
    ) -> TuningContext<'a> {
        Self::build(ContextBackend::External(backend), budget_s, seed)
    }

    fn build(backend: ContextBackend<'a>, budget_s: f64, seed: u64) -> TuningContext<'a> {
        let space = Arc::clone(backend.as_dyn_ref().space());
        TuningContext {
            backend,
            space,
            rng: Rng::new(seed),
            clock_s: 0.0,
            budget_s,
            eval_calls: 0,
            unique_evals: 0,
            seen: HashMap::with_hasher(FxBuildHasher::default()),
            best_ms: f64::INFINITY,
            best_idx: None,
            trajectory: Vec::new(),
            batch_calls: 0,
            batched_evals: 0,
            largest_batch: 0,
            cancel: Vec::new(),
            cancel_observed: Cell::new(false),
        }
    }

    /// Attach a cooperative cancellation token: once it fires, every budget
    /// check reports the budget as spent, so the optimizer winds down at
    /// its next between-evaluations check (`budget_spent_fraction` /
    /// `budget_exhausted` are the natural sites — every registry optimizer
    /// loops on them). Tokens accumulate: calling this again *adds* a
    /// token rather than replacing the first, so a per-job token (the
    /// executor's batch-wide Ctrl-C) and a per-arm token (portfolio
    /// racing's loser cut, attached from inside the optimizer wrapper)
    /// both stay live — whichever fires first cancels the run. The
    /// run-level contract lives in [`Self::cancellation_observed`].
    pub fn set_cancel_token(&mut self, token: CancelToken) {
        self.cancel.push(token);
    }

    /// True once a budget check has observed the fired token. The caller
    /// (the executor's job runner) uses this to classify the run: observed
    /// ⇒ the optimizer's behavior diverged from the drain-all run and the
    /// trajectory must be discarded as *cancelled*; never observed ⇒ the
    /// run is a normal completion, bit-identical to its uncancelled twin
    /// (even if the token fired after the last check).
    pub fn cancellation_observed(&self) -> bool {
        self.cancel_observed.get()
    }

    /// Poll the attached tokens (if any), recording the observation.
    #[inline]
    fn check_cancelled(&self) -> bool {
        if self.cancel.iter().any(CancelToken::is_cancelled) {
            self.cancel_observed.set(true);
            return true;
        }
        false
    }

    /// The search space under tuning.
    #[inline]
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Owned handle to the search space. Optimizers hoist this at the top
    /// of `run`/`suggest` so space queries never borrow the context (the
    /// context's `rng` stays mutably available).
    #[inline]
    pub fn space_handle(&self) -> Arc<SearchSpace> {
        Arc::clone(&self.space)
    }

    /// The backend's space identifier, e.g. `gemm@A100`.
    pub fn backend_id(&self) -> String {
        self.backend.as_dyn_ref().id()
    }

    /// Evaluate configuration `i`; returns the observed mean runtime in ms
    /// (`None` for crashing configurations). Charges wall-clock: full
    /// compile+benchmark cost for new configurations, a bookkeeping
    /// epsilon for repeats. Never skips — budget discipline is the
    /// caller's job on this path (check [`Self::budget_exhausted`]).
    pub fn evaluate(&mut self, i: u32) -> Option<f64> {
        self.eval_calls += 1;
        if let Some(&v) = self.seen.get(&i) {
            self.clock_s += CACHED_EVAL_COST_S;
            return v;
        }
        self.unique_evals += 1;
        let value = self.backend.as_dyn().evaluate_one(i);
        self.clock_s += self.backend.as_dyn_ref().eval_cost_s(i);
        self.record(i, value);
        value
    }

    /// Evaluate a batch of configurations in one backend call (the ask/tell
    /// path). Per-config semantics match a sequential caller that checks
    /// `budget_exhausted()` before each `evaluate`: repeats are charged the
    /// bookkeeping epsilon, fresh configs full cost, and once the budget
    /// (or call cap) is exhausted the remaining configs are skipped and
    /// reported as `None` without being evaluated or charged. Within-batch
    /// duplicates count as repeats of the first occurrence.
    pub fn evaluate_batch(&mut self, configs: &[u32]) -> Vec<Option<f64>> {
        self.batch_calls += 1;
        self.largest_batch = self.largest_batch.max(configs.len());

        // Backends whose costs are only estimates before evaluation
        // (measured backends) are driven config-by-config with the actual
        // clock re-checked between evaluations — a whole-batch plan at
        // estimated costs could overrun the budget by the entire batch.
        // (Measured evaluation is serialized behind the source store
        // anyway, so nothing is lost by not handing it one big batch.)
        if !self.backend.as_dyn_ref().cost_model_exact() {
            return configs
                .iter()
                .map(|&i| if self.budget_exhausted() { None } else { self.evaluate(i) })
                .collect();
        }

        // Plan: decide each config's step and the backend batch up front,
        // with budget cuts projected from the exact per-config costs.
        let mut steps: Vec<Step> = Vec::with_capacity(configs.len());
        let mut to_eval: Vec<u32> = Vec::new();
        let mut planned_clock = self.clock_s;
        let mut planned_calls = self.eval_calls;
        {
            let backend = self.backend.as_dyn_ref();
            let mut fresh: std::collections::HashSet<u32, FxBuildHasher> =
                std::collections::HashSet::with_hasher(FxBuildHasher::default());
            for &i in configs {
                if planned_clock >= self.budget_s
                    || planned_calls >= MAX_EVAL_CALLS
                    || self.check_cancelled()
                {
                    steps.push(Step::Skip);
                    continue;
                }
                planned_calls += 1;
                if self.seen.contains_key(&i) || fresh.contains(&i) {
                    planned_clock += CACHED_EVAL_COST_S;
                    steps.push(Step::Repeat);
                } else {
                    planned_clock += backend.eval_cost_s(i);
                    fresh.insert(i);
                    steps.push(Step::Fresh(to_eval.len()));
                    to_eval.push(i);
                }
            }
        }

        let values = if to_eval.is_empty() {
            Vec::new()
        } else {
            self.batched_evals += to_eval.len() as u64;
            let values = self.backend.as_dyn().evaluate_batch(&to_eval);
            assert_eq!(values.len(), to_eval.len(), "backend batch size mismatch");
            values
        };

        // Commit: charge the clock and stamp the trajectory in submission
        // order, exactly as sequential evaluation would have.
        let mut out = Vec::with_capacity(configs.len());
        for (&i, step) in configs.iter().zip(&steps) {
            match *step {
                Step::Skip => out.push(None),
                Step::Repeat => {
                    self.eval_calls += 1;
                    self.clock_s += CACHED_EVAL_COST_S;
                    let v = self
                        .seen
                        .get(&i)
                        .copied()
                        .expect("repeat step for a never-evaluated config");
                    out.push(v);
                }
                Step::Fresh(slot) => {
                    self.eval_calls += 1;
                    self.unique_evals += 1;
                    self.clock_s += self.backend.as_dyn_ref().eval_cost_s(i);
                    let v = values[slot];
                    self.record(i, v);
                    out.push(v);
                }
            }
        }
        out
    }

    /// Draw a distinct random sample of `k` configurations and evaluate it
    /// as one batch — the population-init idiom shared by DE, ATGW and the
    /// genome interpreter. Stream-preservation argument (stated once,
    /// here): every RNG draw happens before the batch is submitted and
    /// evaluation consumes no RNG, so this is bit-identical to the classic
    /// draw-one-evaluate-one loop of a budget-checking caller; entries the
    /// budget cut off come back as `None`, exactly where that caller would
    /// have stopped.
    pub fn evaluate_random_sample(&mut self, k: usize) -> Vec<(u32, Option<f64>)> {
        let space = self.space_handle();
        let sample = space.random_sample(&mut self.rng, k);
        let values = self.evaluate_batch(&sample);
        sample.into_iter().zip(values).collect()
    }

    /// Draw `k` independent random valid configurations (repeats possible)
    /// and evaluate them as one batch — the restart/reinit twin of
    /// [`Self::evaluate_random_sample`], same stream-preservation
    /// argument.
    pub fn evaluate_random_draws(&mut self, k: usize) -> Vec<(u32, Option<f64>)> {
        let space = self.space_handle();
        let draws: Vec<u32> = (0..k).map(|_| space.random_valid(&mut self.rng)).collect();
        let values = self.evaluate_batch(&draws);
        draws.into_iter().zip(values).collect()
    }

    /// Record a freshly evaluated config: dedup map + best/trajectory.
    fn record(&mut self, i: u32, value: Option<f64>) {
        self.seen.insert(i, value);
        if let Some(v) = value {
            if v < self.best_ms {
                self.best_ms = v;
                self.best_idx = Some(i);
                self.trajectory.push((self.clock_s, v));
            }
        }
    }

    /// True when the time budget (or the call-count safety cap) is spent,
    /// or a cancellation token has fired (cancellation presents as budget
    /// exhaustion so every optimizer's existing check site honors it).
    #[inline]
    pub fn budget_exhausted(&self) -> bool {
        self.clock_s >= self.budget_s
            || self.eval_calls >= MAX_EVAL_CALLS
            || self.check_cancelled()
    }

    /// Fraction of the time budget consumed, clamped to [0, 1]. A
    /// non-positive budget reports 1.0 (fully spent) rather than NaN —
    /// generated-optimizer schedules branch on this value, and NaN would
    /// silently disable every `fraction < x` phase switch. A fired
    /// cancellation token also reports 1.0 (fully spent) — but, as in
    /// [`Self::budget_exhausted`], only a run whose budget is *not*
    /// already naturally spent polls the token: a run in its final stretch
    /// answers 1.0 from the clock alone and is never misclassified as
    /// cancelled when its behavior could not have diverged.
    #[inline]
    pub fn budget_spent_fraction(&self) -> f64 {
        if self.budget_s <= 0.0 {
            return 1.0;
        }
        let fraction = self.clock_s / self.budget_s;
        if fraction >= 1.0 {
            return 1.0;
        }
        if self.check_cancelled() {
            return 1.0;
        }
        fraction
    }

    pub fn elapsed_s(&self) -> f64 {
        self.clock_s
    }

    pub fn budget_s(&self) -> f64 {
        self.budget_s
    }

    /// Best configuration found so far with its observed runtime.
    pub fn best(&self) -> Option<(u32, f64)> {
        self.best_idx.map(|i| (i, self.best_ms))
    }

    pub fn unique_evals(&self) -> u64 {
        self.unique_evals
    }

    pub fn eval_calls(&self) -> u64 {
        self.eval_calls
    }

    /// Number of [`Self::evaluate_batch`] submissions so far.
    pub fn batch_calls(&self) -> u64 {
        self.batch_calls
    }

    /// Fresh evaluations that reached the backend through the batch path.
    pub fn batched_evals(&self) -> u64 {
        self.batched_evals
    }

    /// Largest batch submitted so far (tests assert population optimizers
    /// really send whole generations).
    pub fn largest_batch(&self) -> usize {
        self.largest_batch
    }

    /// Whether `i` has been evaluated already (tabu-style checks).
    pub fn already_evaluated(&self, i: u32) -> bool {
        self.seen.contains_key(&i)
    }

    /// Observed value of an already-evaluated config (no time charged).
    pub fn peek(&self, i: u32) -> Option<Option<f64>> {
        self.seen.get(&i).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;

    fn ctx_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    #[test]
    fn clock_advances_and_dedup_is_cheap() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 1e9, 1);
        let t0 = ctx.elapsed_s();
        ctx.evaluate(0);
        let t1 = ctx.elapsed_s();
        assert!(t1 > t0 + 0.1); // compile time at least
        ctx.evaluate(0);
        let t2 = ctx.elapsed_s();
        assert!(t2 - t1 < CACHED_EVAL_COST_S + 1e-9); // cached
        assert_eq!(ctx.unique_evals(), 1);
        assert_eq!(ctx.eval_calls(), 2);
    }

    #[test]
    fn best_tracks_improvements_only() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 1e9, 2);
        for i in 0..100u32 {
            ctx.evaluate(i);
        }
        let (best_i, best_v) = ctx.best().unwrap();
        // Trajectory is strictly decreasing in value, increasing in time.
        let tr = &ctx.trajectory;
        assert!(tr.windows(2).all(|w| w[1].1 < w[0].1 && w[1].0 >= w[0].0));
        assert_eq!(tr.last().unwrap().1, best_v);
        assert!(ctx.peek(best_i).unwrap().unwrap() == best_v);
    }

    #[test]
    fn budget_exhaustion() {
        let cache = ctx_cache();
        let mut ctx = TuningContext::new(&cache, 10.0, 3);
        let mut n = 0;
        while !ctx.budget_exhausted() {
            ctx.evaluate(n);
            n += 1;
        }
        assert!(ctx.elapsed_s() >= 10.0);
        assert!(ctx.budget_spent_fraction() >= 1.0 - 1e-12);
        assert!(n < 100, "budget should bound evals, got {}", n);
    }

    #[test]
    fn zero_budget_reports_fully_spent_not_nan() {
        let cache = ctx_cache();
        let ctx = TuningContext::new(&cache, 0.0, 4);
        assert_eq!(ctx.budget_spent_fraction(), 1.0);
        assert!(ctx.budget_exhausted());
        let neg = TuningContext::new(&cache, -5.0, 4);
        assert_eq!(neg.budget_spent_fraction(), 1.0);
    }

    #[test]
    fn cancellation_presents_as_budget_exhaustion_and_is_observed() {
        let cache = ctx_cache();
        let token = crate::util::cancel::CancelToken::new();
        let mut ctx = TuningContext::new(&cache, 1e9, 6);
        ctx.set_cancel_token(token.clone());
        assert!(!ctx.budget_exhausted());
        assert!(!ctx.cancellation_observed(), "unfired token must not mark the run");
        ctx.evaluate(0);
        token.cancel();
        assert!(ctx.budget_exhausted());
        assert_eq!(ctx.budget_spent_fraction(), 1.0);
        assert!(ctx.cancellation_observed());
        // A fired token also cuts batch submissions: the whole batch is
        // skipped, nothing evaluated or charged.
        let before = ctx.eval_calls();
        assert!(ctx.evaluate_batch(&[1, 2, 3]).iter().all(Option::is_none));
        assert_eq!(ctx.eval_calls(), before);
    }

    #[test]
    fn any_of_several_tokens_cancels_the_run() {
        // Multi-token attachment: the batch-wide token and a per-arm
        // token coexist; whichever fires first is observed.
        let cache = ctx_cache();
        let batch_token = CancelToken::new();
        let arm_token = CancelToken::new();
        let mut ctx = TuningContext::new(&cache, 1e9, 6);
        ctx.set_cancel_token(batch_token.clone());
        ctx.set_cancel_token(arm_token.clone());
        assert!(!ctx.budget_exhausted());
        arm_token.cancel();
        assert!(ctx.budget_exhausted(), "second token must cancel too");
        assert!(ctx.cancellation_observed());
        assert!(!batch_token.is_cancelled(), "tokens stay independent");
    }

    #[test]
    fn unobserved_token_leaves_the_run_untouched() {
        // A token that fires but is never polled must not change anything:
        // the run's outputs stay bit-identical to the token-less run.
        let cache = ctx_cache();
        let plain = {
            let mut ctx = TuningContext::new(&cache, 1e9, 8);
            let vals: Vec<_> = (0..10u32).map(|i| ctx.evaluate(i)).collect();
            (vals, ctx.trajectory.clone(), ctx.elapsed_s())
        };
        let with_token = {
            let mut ctx = TuningContext::new(&cache, 1e9, 8);
            ctx.set_cancel_token(CancelToken::new());
            let vals: Vec<_> = (0..10u32).map(|i| ctx.evaluate(i)).collect();
            assert!(!ctx.cancellation_observed());
            (vals, ctx.trajectory.clone(), ctx.elapsed_s())
        };
        assert_eq!(plain, with_token);
    }

    #[test]
    fn observed_values_reproducible_per_seed() {
        let cache = ctx_cache();
        let a = {
            let mut ctx = TuningContext::new(&cache, 1e9, 7);
            (0..20u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        let b = {
            let mut ctx = TuningContext::new(&cache, 1e9, 7);
            (0..20u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn batch_matches_sequential_exactly() {
        let cache = ctx_cache();
        // Mixed sequence with repeats and within-batch duplicates.
        let configs: Vec<u32> = vec![5, 9, 5, 13, 9, 21, 5, 34];
        let mut seq = TuningContext::new(&cache, 1e9, 11);
        let seq_vals: Vec<Option<f64>> = configs.iter().map(|&i| seq.evaluate(i)).collect();
        let mut bat = TuningContext::new(&cache, 1e9, 11);
        let bat_vals = bat.evaluate_batch(&configs);
        assert_eq!(seq_vals, bat_vals);
        assert_eq!(seq.elapsed_s(), bat.elapsed_s());
        assert_eq!(seq.trajectory, bat.trajectory);
        assert_eq!(seq.unique_evals(), bat.unique_evals());
        assert_eq!(seq.eval_calls(), bat.eval_calls());
        assert_eq!(bat.batched_evals(), 5, "five distinct configs");
        assert_eq!(bat.largest_batch(), configs.len());
    }

    #[test]
    fn batch_cuts_at_budget_like_a_checking_caller() {
        let cache = ctx_cache();
        let configs: Vec<u32> = (0..200).collect();
        // Sequential caller that checks the budget before each evaluation.
        let mut seq = TuningContext::new(&cache, 25.0, 5);
        let mut seq_vals = Vec::new();
        for &i in &configs {
            if seq.budget_exhausted() {
                seq_vals.push(None);
                continue;
            }
            seq_vals.push(seq.evaluate(i));
        }
        let mut bat = TuningContext::new(&cache, 25.0, 5);
        let bat_vals = bat.evaluate_batch(&configs);
        assert_eq!(seq_vals, bat_vals);
        assert_eq!(seq.elapsed_s(), bat.elapsed_s());
        assert_eq!(seq.trajectory, bat.trajectory);
        assert!(bat.unique_evals() < 200, "budget must cut the batch");
    }

    #[test]
    fn external_backend_drives_identically() {
        let cache = ctx_cache();
        let inline = {
            let mut ctx = TuningContext::new(&cache, 1e9, 9);
            (0..30u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        let external = {
            let mut backend = CachedBackend::new(&cache);
            let mut ctx = TuningContext::with_backend(&mut backend, 1e9, 9);
            assert_eq!(ctx.backend_id(), cache.id());
            (0..30u32).filter_map(|i| ctx.evaluate(i)).sum::<f64>()
        };
        assert_eq!(inline, external);
    }
}
