//! Pluggable evaluation backends: the seam between optimization algorithms
//! and whatever actually produces objective values.
//!
//! [`EvalBackend`] is the cost-function interface the whole system programs
//! against. [`TuningContext`](super::TuningContext) sits on top of any
//! backend and keeps the run-level semantics (dedup, simulated wall clock,
//! best-so-far trajectory, `budget_spent_fraction`); the backend below it
//! answers "what does configuration `i` cost and score". Two backends ship:
//!
//! - [`CachedBackend`] replays a pre-explored [`Cache`] ("simulation
//!   mode"), byte-identical to the pre-backend evaluator: the k-th unique
//!   evaluation of a run draws the same deterministic noise stream whether
//!   it arrives alone or inside a batch.
//! - `MeasuredBackend` (`crate::runtime::measured`) compiles and times AOT
//!   program variants on demand over PJRT — the real-system path.
//!
//! [`BackendSource`] mints a fresh backend per tuning run, which is what a
//! `TuningJob` carries: per-run backends keep noise/measurement state
//! run-local while the source (a shared `Cache`, a shared measurement
//! store) is safely shared across scheduler workers.

use std::sync::Arc;

use super::cache::{Cache, RUNS_PER_EVAL};
use crate::searchspace::SearchSpace;

/// A batch-capable, budget-accounted evaluation backend for one search
/// space.
///
/// Backends are stateful per run (deterministic noise streams, lazy
/// measurement stores), so callers must submit only configurations they
/// will actually consume, in evaluation order. The `TuningContext`
/// guarantees this: deduplication and budget cuts happen above this seam,
/// and each unique configuration reaches the backend exactly once.
pub trait EvalBackend {
    /// Handle to the search space being tuned.
    fn space(&self) -> &Arc<SearchSpace>;

    /// Stable space identifier, e.g. `gemm@A100` or `gemm-measured`.
    fn id(&self) -> String;

    /// Wall-clock seconds one evaluation of `i` costs (compile + benchmark
    /// repetitions). Simulated backends know this a priori; measured
    /// backends return an estimate before `i` has been measured and the
    /// actual recorded cost afterwards.
    fn eval_cost_s(&self, i: u32) -> f64;

    /// Whether [`Self::eval_cost_s`] is exact before evaluation (true for
    /// simulated backends) or an estimate until measured. The
    /// `TuningContext` plans whole-batch submissions only for exact-cost
    /// backends; estimating backends are driven config-by-config so a
    /// batch cannot overrun the budget by more than one evaluation.
    fn cost_model_exact(&self) -> bool {
        true
    }

    /// Evaluate configurations in order; one observed mean runtime (ms) per
    /// entry, `None` for crashing configurations. The returned vector has
    /// exactly `configs.len()` entries.
    fn evaluate_batch(&mut self, configs: &[u32]) -> Vec<Option<f64>>;

    /// Single-configuration path, semantically `evaluate_batch(&[i])[0]`.
    /// Backends override this to skip the per-call allocation on the
    /// sequential hot path.
    fn evaluate_one(&mut self, i: u32) -> Option<f64> {
        self.evaluate_batch(std::slice::from_ref(&i))
            .pop()
            .expect("evaluate_batch returned an empty batch")
    }
}

/// Simulation-mode backend: replays a pre-explored [`Cache`].
///
/// Holds the run's unique-evaluation counter, which keys the deterministic
/// measurement-noise stream: the k-th unique evaluation draws observation
/// indices `k*(RUNS_PER_EVAL+1) .. +RUNS_PER_EVAL`, exactly as the
/// pre-backend `TuningContext` did — so cached-backend runs reproduce
/// pre-redesign results bit-for-bit, batched or not.
pub struct CachedBackend<'c> {
    cache: &'c Cache,
    evals: u64,
}

impl<'c> CachedBackend<'c> {
    pub fn new(cache: &'c Cache) -> CachedBackend<'c> {
        CachedBackend { cache, evals: 0 }
    }

    /// The underlying cache (baseline/statistics access for reports).
    pub fn cache(&self) -> &'c Cache {
        self.cache
    }
}

impl EvalBackend for CachedBackend<'_> {
    fn space(&self) -> &Arc<SearchSpace> {
        &self.cache.space
    }

    fn id(&self) -> String {
        self.cache.id()
    }

    fn eval_cost_s(&self, i: u32) -> f64 {
        self.cache.eval_cost_s(i)
    }

    fn evaluate_batch(&mut self, configs: &[u32]) -> Vec<Option<f64>> {
        configs.iter().map(|&i| self.evaluate_one(i)).collect()
    }

    fn evaluate_one(&mut self, i: u32) -> Option<f64> {
        self.evals += 1;
        // Observed value: mean over the benchmark repetitions, drawn from
        // the noise stream keyed by this run's unique-evaluation ordinal.
        // The fused cache call is bit-identical to the per-draw
        // `observe_ms` loop (pinned by `observe_mean_matches_per_draw_loop`).
        let base = self.evals.wrapping_mul(RUNS_PER_EVAL as u64 + 1);
        self.cache.observe_mean_ms(i, base, RUNS_PER_EVAL)
    }
}

/// Mints a fresh [`EvalBackend`] per tuning run.
///
/// This is what jobs and the runner carry: the source is shared (and
/// `Sync`) across scheduler workers, while each run gets its own backend
/// so per-run state (noise ordinals, budget-relevant cost recording) never
/// leaks between seeds.
pub trait BackendSource: Sync {
    /// A fresh backend for one run.
    fn backend(&self) -> Box<dyn EvalBackend + '_>;

    /// Stable space identifier (used for seed derivation and reports);
    /// matches the id of every backend this source mints.
    fn space_id(&self) -> String;
}

impl BackendSource for Cache {
    fn backend(&self) -> Box<dyn EvalBackend + '_> {
        Box::new(CachedBackend::new(self))
    }

    fn space_id(&self) -> String {
        self.id()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gpu::GpuSpec;
    use crate::searchspace::Application;

    fn small_cache() -> Cache {
        Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
    }

    #[test]
    fn batch_and_single_draw_the_same_noise_stream() {
        let cache = small_cache();
        let seq: Vec<Option<f64>> = {
            let mut b = CachedBackend::new(&cache);
            (0..40u32).map(|i| b.evaluate_one(i)).collect()
        };
        let batched = {
            let mut b = CachedBackend::new(&cache);
            let configs: Vec<u32> = (0..40).collect();
            b.evaluate_batch(&configs)
        };
        assert_eq!(seq, batched);
    }

    #[test]
    fn noise_ordinal_is_run_local() {
        // Two fresh backends over the same cache replay identical streams;
        // evaluation order changes observed values (ordinal-keyed noise),
        // exactly as the pre-backend evaluator behaved.
        let cache = small_cache();
        let mut a = CachedBackend::new(&cache);
        let mut b = CachedBackend::new(&cache);
        assert_eq!(a.evaluate_one(3), b.evaluate_one(3));
        let mut c = CachedBackend::new(&cache);
        c.evaluate_one(9); // shifts the ordinal
        let shifted = c.evaluate_one(3);
        if let (Some(x), Some(y)) = (a.evaluate_one(5), shifted) {
            assert!(x.is_finite() && y.is_finite());
        }
    }

    #[test]
    fn source_mints_fresh_backends() {
        let cache = small_cache();
        let source: &dyn BackendSource = &cache;
        assert_eq!(source.space_id(), cache.id());
        let first = source.backend().evaluate_one(0);
        let again = source.backend().evaluate_one(0);
        assert_eq!(first, again, "each run must restart the noise stream");
    }

    #[test]
    fn costs_match_cache_accounting() {
        let cache = small_cache();
        let b = CachedBackend::new(&cache);
        for i in 0..10u32 {
            assert_eq!(b.eval_cost_s(i), cache.eval_cost_s(i));
        }
        assert_eq!(b.id(), cache.id());
        assert_eq!(b.space().len(), cache.len());
    }
}
