//! Integration: the methodology end-to-end — budgets, curves, aggregation
//! — behaves per the paper's definitions on real caches.

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::{aggregate, run_many, Baseline, NamedFactory, SpaceSetup};
use llamea_kt::optimizers::Optimizer;
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::Cache;

#[test]
fn random_search_scores_near_zero_on_average() {
    // Definitional property: the baseline IS expected random search, so
    // random search must aggregate to ~0 over enough runs.
    let cache = Cache::build(Application::Hotspot, GpuSpec::by_name("A100").unwrap());
    let setup = SpaceSetup::new(&cache);
    let curves = run_many(&cache, &setup, &NamedFactory("random".into()), 60, 5);
    let agg = aggregate(&[curves]);
    assert!(agg.score.abs() < 0.15, "random scored {:+.3}", agg.score);
}

#[test]
fn budgets_scale_with_eval_cost() {
    // A GPU with slower kernels (W6600) must get a longer absolute budget
    // for the same application than a fast one when per-eval cost grows.
    let a100 = Cache::build(Application::Convolution, GpuSpec::by_name("A100").unwrap());
    let w6600 = Cache::build(Application::Convolution, GpuSpec::by_name("W6600").unwrap());
    assert!(w6600.mean_eval_cost_s > a100.mean_eval_cost_s);
}

#[test]
fn curves_are_bounded_and_scores_finite() {
    let cache = Cache::build(Application::Gemm, GpuSpec::by_name("A4000").unwrap());
    let setup = SpaceSetup::new(&cache);
    for name in ["ga", "hybrid_vndx", "sa"] {
        let curves = run_many(&cache, &setup, &NamedFactory(name.into()), 10, 1);
        for c in &curves {
            assert_eq!(c.len(), setup.times.len());
            assert!(c.iter().all(|&x| (-1.0..=1.0).contains(&x)), "{}", name);
        }
        let agg = aggregate(&[curves]);
        assert!(agg.score.is_finite());
        assert_eq!(agg.ci95.len(), setup.times.len());
    }
}

#[test]
fn perfect_knowledge_scores_one() {
    // An "oracle" that immediately evaluates the optimum config scores ~1.
    struct Oracle(u32);
    impl llamea_kt::optimizers::Optimizer for Oracle {
        fn name(&self) -> &str { "oracle" }
        fn run(&mut self, ctx: &mut llamea_kt::tuning::TuningContext) {
            ctx.evaluate(self.0);
            while !ctx.budget_exhausted() { ctx.evaluate(self.0); }
        }
    }
    let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A100").unwrap());
    let best_idx = cache
        .mean_ms
        .iter()
        .enumerate()
        .filter(|(_, t)| t.is_finite())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32;
    let setup = SpaceSetup::new(&cache);
    let baseline = Baseline::from_cache(&cache);
    let mut ctx = llamea_kt::tuning::TuningContext::new(&cache, setup.budget_s, 1);
    Oracle(best_idx).run(&mut ctx);
    let (_, best) = ctx.best().unwrap();
    // Observed value is noisy around the optimum; P at the end ~ 1.
    let p_end = (baseline.value_at(setup.budget_s) - best)
        / (baseline.value_at(setup.budget_s) - baseline.optimum());
    assert!(p_end > 0.8, "oracle P {}", p_end);
}
