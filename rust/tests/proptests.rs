//! Property-based tests over coordinator invariants (routing of configs
//! through the space API, constraint evaluation, methodology math), using
//! the in-repo mini-proptest framework (offline `proptest` substitute).

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::Baseline;
use llamea_kt::searchspace::{Application, NeighborKind};
use llamea_kt::tuning::Cache;
use llamea_kt::util::proptest::check;
use llamea_kt::util::rng::Rng;
use llamea_kt::util::stats;

fn conv_space() -> llamea_kt::searchspace::SearchSpace {
    Application::Convolution.build_space()
}

#[test]
fn prop_index_roundtrip() {
    let space = conv_space();
    check("index_of(config(i)) == i", 512, |rng: &mut Rng| {
        let i = rng.below(space.len()) as u32;
        assert_eq!(space.index_of(space.config(i)), Some(i));
    });
}

#[test]
fn prop_neighbors_symmetric() {
    let space = conv_space();
    check("hamming neighborhood is symmetric", 128, |rng: &mut Rng| {
        let i = rng.below(space.len()) as u32;
        for j in space.neighbors(i, NeighborKind::Hamming) {
            let back = space.neighbors(j, NeighborKind::Hamming);
            assert!(back.contains(&i), "{} -> {} not symmetric", i, j);
        }
    });
}

#[test]
fn prop_repair_idempotent_on_valid() {
    let space = conv_space();
    check("repair(valid) == identity", 256, |rng: &mut Rng| {
        let i = rng.below(space.len()) as u32;
        let cfg = space.config(i).to_vec();
        assert_eq!(space.repair(&cfg, rng), i);
    });
}

#[test]
fn prop_constraint_eval_matches_membership() {
    // For arbitrary raw assignments: membership in the enumerated space
    // must equal direct constraint evaluation.
    let space = conv_space();
    check("membership == constraints", 512, |rng: &mut Rng| {
        let cfg: Vec<u16> = (0..space.dims())
            .map(|d| rng.below(space.params.params[d].cardinality()) as u16)
            .collect();
        let member = space.index_of(&cfg).is_some();
        let satisfies = space.satisfies_constraints(&cfg);
        assert_eq!(member, satisfies, "cfg {:?}", cfg);
    });
}

#[test]
fn prop_expected_best_monotone_in_draws() {
    let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
    let baseline = Baseline::from_cache(&cache);
    check("E[best|n] monotone non-increasing", 128, |rng: &mut Rng| {
        let n1 = 1 + rng.below(5000) as u64;
        let n2 = n1 + 1 + rng.below(5000) as u64;
        assert!(baseline.expected_best_after(n2) <= baseline.expected_best_after(n1) + 1e-9);
    });
}

#[test]
fn prop_running_min_invariants() {
    check("running_min is monotone lower envelope", 256, |rng: &mut Rng| {
        let xs: Vec<f64> = (0..1 + rng.below(40)).map(|_| rng.f64() * 100.0).collect();
        let rm = stats::running_min(&xs);
        assert_eq!(rm.len(), xs.len());
        for k in 0..xs.len() {
            assert!(rm[k] <= xs[k]);
            if k > 0 {
                assert!(rm[k] <= rm[k - 1]);
            }
            let true_min = xs[..=k].iter().cloned().fold(f64::INFINITY, f64::min);
            assert_eq!(rm[k], true_min);
        }
    });
}

#[test]
fn prop_percentile_bounds_and_order() {
    check("percentiles ordered and bounded", 256, |rng: &mut Rng| {
        let xs: Vec<f64> = (0..2 + rng.below(50)).map(|_| rng.normal() * 10.0).collect();
        let q1 = rng.f64() * 100.0;
        let q2 = rng.f64() * 100.0;
        let (lo, hi) = if q1 <= q2 { (q1, q2) } else { (q2, q1) };
        let p_lo = stats::percentile(&xs, lo);
        let p_hi = stats::percentile(&xs, hi);
        assert!(p_lo <= p_hi + 1e-12);
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(p_lo >= min - 1e-12 && p_hi <= max + 1e-12);
    });
}

#[test]
fn prop_tuning_context_accounting() {
    // State-machine property: for any random sequence of evaluate calls,
    // unique <= calls, clock is non-decreasing, best is the min over
    // successful observations.
    let cache = Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap());
    check("context accounting", 64, |rng: &mut Rng| {
        let mut ctx = llamea_kt::tuning::TuningContext::new(&cache, 1e9, rng.next_u64());
        let mut best = f64::INFINITY;
        let mut prev_clock = 0.0;
        for _ in 0..rng.below(200) {
            let i = rng.below(cache.len()) as u32;
            if let Some(v) = ctx.evaluate(i) {
                best = best.min(v);
            }
            assert!(ctx.elapsed_s() >= prev_clock);
            prev_clock = ctx.elapsed_s();
        }
        assert!(ctx.unique_evals() <= ctx.eval_calls());
        if best.is_finite() {
            assert_eq!(ctx.best().unwrap().1, best);
        }
    });
}

#[test]
fn prop_genome_mutation_closure() {
    // Any chain of mock-LLM mutations keeps genomes valid (the closure
    // property the evolution loop relies on).
    use llamea_kt::llamea::{Generation, Genome, LlmClient, MockLlm, MutationPrompt, Prompt};
    check("mutation closure", 64, |rng: &mut Rng| {
        let mut llm = MockLlm::new(rng.next_u64());
        llm.failure_rate = 0.0;
        let mut g = Genome::hybrid_vndx_like();
        for _ in 0..rng.below(8) {
            let op = *rng.choose(&MutationPrompt::ALL);
            let p = Prompt::task("gemm").mutate(g.clone(), op);
            if let (Generation::Code(next), _) = llm.generate(&p) {
                assert!(next.is_valid(), "{:?}", next);
                g = next;
            }
        }
    });
}
