//! Integration tests for the persistent cache store and sharded execution
//! (ISSUE 6 acceptance):
//!
//! - round-trip identity: a cache built at width 1 and at width 8, saved
//!   and loaded back (both owned-read and mmap modes), is byte-identical
//!   to a fresh build — arenas and summary stats alike;
//! - fingerprint safety: a file stamped with a foreign fingerprint (a
//!   stale spec, flipped salt, or bumped format) is rejected and rebuilt,
//!   never silently reused;
//! - corruption safety: truncated or bit-flipped files are rejected;
//! - the registry warm path loads each key exactly once under concurrent
//!   access, and a warm run produces bit-identical reports to a cold one;
//! - shard-merge: per-shard partial reports of an uneven K/N split merge
//!   into exactly the single-process report, byte for byte, including
//!   the `"jobs"` block.

use std::path::PathBuf;
use std::sync::Arc;

use llamea_kt::coordinator::{
    collate_groups, grid_aggregates, grid_jobs, merge_reports, partial_coordinate_json,
    scores_json, CacheKey, CacheOutcome, CacheRegistry, JobsSummary, ShardJob, ShardSpec,
};
use llamea_kt::hypertune::{
    sweep, sweep_json, sweep_partial_json, MetaStrategy, MetaTuning, SweepOutcome,
};
use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::OptimizerFactory;
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::persist::{
    cache_fp, cache_path, load_cache, load_space, save_cache, save_cache_tagged, save_space,
    save_space_tagged, space_fp, space_path, LoadError, LoadMode,
};
use llamea_kt::searchspace::{Application, NeighborKind};
use llamea_kt::tuning::Cache;
use llamea_kt::util::json::Json;

/// A unique temp dir per test (tests share one process).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("llkt-persist-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const APP: Application = Application::Convolution;

fn gpu() -> &'static GpuSpec {
    GpuSpec::by_name("A4000").unwrap()
}

#[test]
fn cache_roundtrip_is_byte_identical_at_widths_1_and_8() {
    let dir = tmp_dir("roundtrip");
    let space = Arc::new(APP.build_space());
    let w1 = Cache::build_with_space_width(APP, gpu(), Arc::clone(&space), 1);
    let w8 = Cache::build_with_space_width(APP, gpu(), Arc::clone(&space), 8);
    assert_eq!(&w1.mean_ms[..], &w8.mean_ms[..], "cold builds must not depend on width");
    assert_eq!(&w1.compile_s[..], &w8.compile_s[..]);

    // Save the wide build; load in both modes; everything must match the
    // width-1 build bit for bit.
    let spath = space_path(&dir, APP);
    let cpath = cache_path(&dir, APP, gpu().name);
    save_space(&spath, &space).unwrap();
    save_cache(&cpath, &w8).unwrap();
    for mode in [LoadMode::Read, LoadMode::Mmap] {
        let lspace = load_space(&spath, APP, mode).unwrap();
        assert_eq!(lspace.config_arena(), space.config_arena(), "{mode:?}");
        for k in NeighborKind::ALL {
            // save_space persists every graph; the loaded ones must be
            // present (no lazy rebuild) and identical.
            assert!(lspace.has_graph(k), "{mode:?} {k:?}");
            assert_eq!(lspace.graph_parts(k), space.graph_parts(k), "{mode:?} {k:?}");
        }
        assert_eq!(space_fp(&lspace), space_fp(&space));

        let loaded = load_cache(&cpath, APP, gpu(), Arc::new(lspace), mode).unwrap();
        assert_eq!(&loaded.mean_ms[..], &w1.mean_ms[..], "{mode:?}");
        assert_eq!(&loaded.compile_s[..], &w1.compile_s[..], "{mode:?}");
        assert_eq!(loaded.optimum_ms.to_bits(), w1.optimum_ms.to_bits(), "{mode:?}");
        assert_eq!(loaded.median_ms.to_bits(), w1.median_ms.to_bits(), "{mode:?}");
        assert_eq!(
            loaded.mean_eval_cost_s.to_bits(),
            w1.mean_eval_cost_s.to_bits(),
            "{mode:?}"
        );
        assert_eq!(loaded.salt, w1.salt);
        assert_eq!(cache_fp(&loaded), cache_fp(&w1));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn foreign_fingerprints_are_rejected_and_rebuilt() {
    let dir = tmp_dir("fingerprint");
    let space = Arc::new(APP.build_space());
    let cache = Cache::build_with_space(APP, gpu(), Arc::clone(&space));
    let spath = space_path(&dir, APP);
    let cpath = cache_path(&dir, APP, gpu().name);

    // Direct load surface: a flipped fingerprint (stale spec, different
    // salt, bumped model revision — all collapse to "wrong u64") rejects.
    save_space_tagged(&spath, &space, space_fp(&space) ^ 1).unwrap();
    match load_space(&spath, APP, LoadMode::Read) {
        Err(LoadError::Fingerprint { .. }) => {}
        other => panic!("expected fingerprint rejection, got {other:?}"),
    }
    save_cache_tagged(&cpath, &cache, cache_fp(&cache) ^ 1).unwrap();
    match load_cache(&cpath, APP, gpu(), Arc::clone(&space), LoadMode::Mmap) {
        Err(LoadError::Fingerprint { .. }) => {}
        other => panic!("expected fingerprint rejection, got {other:?}"),
    }

    // Registry surface: stale files are rebuilt (never reused) and the
    // rebuild overwrites them with correctly-stamped ones.
    let reg = CacheRegistry::new();
    reg.set_cache_dir(Some(dir.clone()));
    let key = CacheKey::new(APP, gpu());
    let entry = reg.entry(key);
    assert_eq!(reg.builds(), 1, "stale cache must rebuild");
    assert_eq!(reg.loads(), 0);
    assert_eq!(reg.space_builds(), 1, "stale space must rebuild");
    assert_eq!(&entry.cache.mean_ms[..], &cache.mean_ms[..]);

    // The overwritten files now load cleanly in a fresh registry.
    let reg2 = CacheRegistry::new();
    reg2.set_cache_dir(Some(dir.clone()));
    let entry2 = reg2.entry(key);
    assert_eq!(reg2.builds(), 0, "rewritten store must warm-start");
    assert_eq!(reg2.loads(), 1);
    assert_eq!(reg2.space_loads(), 1);
    assert_eq!(&entry2.cache.mean_ms[..], &entry.cache.mean_ms[..]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_and_corrupt_files_are_rejected() {
    let dir = tmp_dir("corrupt");
    let space = Arc::new(APP.build_space());
    let cache = Cache::build_with_space(APP, gpu(), Arc::clone(&space));
    let cpath = cache_path(&dir, APP, gpu().name);
    save_cache(&cpath, &cache).unwrap();
    let good = std::fs::read(&cpath).unwrap();

    // Truncation (a killed writer that somehow bypassed the atomic
    // rename) is rejected, not mis-read.
    std::fs::write(&cpath, &good[..good.len() / 2]).unwrap();
    assert!(
        !matches!(
            load_cache(&cpath, APP, gpu(), Arc::clone(&space), LoadMode::Read),
            Ok(_) | Err(LoadError::Missing)
        ),
        "truncated file must be rejected"
    );

    // A single flipped payload bit is caught by the checksums.
    let mut flipped = good.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x40;
    std::fs::write(&cpath, &flipped).unwrap();
    assert!(
        load_cache(&cpath, APP, gpu(), Arc::clone(&space), LoadMode::Read).is_err(),
        "bit-flipped file must be rejected"
    );

    // Garbage shorter than a header is rejected; the registry falls back
    // to a cold build and heals the file.
    std::fs::write(&cpath, b"not a store file").unwrap();
    let reg = CacheRegistry::new();
    reg.set_cache_dir(Some(dir.clone()));
    reg.entry(CacheKey::new(APP, gpu()));
    assert_eq!((reg.builds(), reg.loads()), (1, 0));
    assert!(
        load_cache(&cpath, APP, gpu(), Arc::clone(&space), LoadMode::Read).is_ok(),
        "registry rebuild must heal the corrupt file"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_registry_access_loads_exactly_once() {
    let dir = tmp_dir("concurrent");
    // Pre-populate the store.
    {
        let reg = CacheRegistry::new();
        reg.set_cache_dir(Some(dir.clone()));
        reg.entry(CacheKey::new(APP, gpu()));
        assert_eq!(reg.builds(), 1);
    }
    // A fresh process-equivalent: 8 threads race the same key; the file
    // is mapped exactly once and nothing is rebuilt.
    let reg = CacheRegistry::new();
    reg.set_cache_dir(Some(dir.clone()));
    let key = CacheKey::new(APP, gpu());
    std::thread::scope(|scope| {
        for _ in 0..8 {
            scope.spawn(|| {
                let e = reg.entry(key);
                assert!(e.cache.len() > 0);
            });
        }
    });
    assert_eq!(reg.builds(), 0, "warm store must satisfy all threads");
    assert_eq!(reg.loads(), 1, "the cache file must be loaded exactly once");
    assert_eq!(reg.space_loads(), 1, "the space file must be loaded exactly once");
    let events = reg.events();
    assert_eq!(events.len(), 2);
    assert!(events.iter().all(|e| e.outcome == CacheOutcome::Loaded));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_and_cold_reports_are_byte_identical() {
    let dir = tmp_dir("warm-report");
    let key = CacheKey::new(APP, gpu());
    let specs = [OptimizerSpec::named("random"), OptimizerSpec::named("sa")];
    let report = |reg: &CacheRegistry| -> String {
        let entries = vec![reg.entry(key)];
        let factories: Vec<(String, &dyn OptimizerFactory)> =
            specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
        let jobs = grid_jobs(&entries, &factories, 3, 11);
        let curves: Vec<Vec<f64>> = jobs.iter().map(|j| j.execute()).collect();
        let groups: Vec<usize> = jobs.iter().map(|j| j.group).collect();
        let grouped = collate_groups(factories.len(), &groups, curves);
        let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
        let results = grid_aggregates(&labels, 1, grouped);
        let ids = vec![entries[0].cache.id()];
        let summary = JobsSummary {
            completed: jobs.len(),
            cancelled: 0,
            failed: 0,
            cost_us: jobs.iter().map(|j| j.cost_us()).sum(),
        };
        scores_json("t", &ids, &results, &summary).to_pretty()
    };

    let cold = CacheRegistry::new();
    let cold_report = report(&cold);
    let seed_store = CacheRegistry::new();
    seed_store.set_cache_dir(Some(dir.clone()));
    let first = report(&seed_store); // builds + saves
    assert_eq!(first, cold_report);
    let warm = CacheRegistry::new();
    warm.set_cache_dir(Some(dir.clone()));
    let warm_report = report(&warm);
    assert_eq!(warm.loads(), 1, "second store run must be warm");
    assert_eq!(warm.builds(), 0);
    assert_eq!(warm_report, cold_report, "warm-start must not change any report byte");

    // The "caches" block is the one legitimate difference between warm
    // and cold runs — which is exactly why reports carry it as a
    // strippable top-level key rather than folding it into the scores.
    let mut with_block = Json::parse(&warm_report).unwrap();
    with_block.set("caches", warm.caches_json());
    assert_ne!(with_block.to_pretty(), cold_report);
    with_block.remove("caches");
    assert_eq!(with_block.to_pretty(), cold_report);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Serialize a partial as the CLI would and parse it back — the merge
/// must survive the actual file round trip (f64s included).
fn through_file(j: Json) -> Json {
    Json::parse(&j.to_pretty()).unwrap()
}

#[test]
fn shard_merge_reproduces_the_coordinate_report_bit_for_bit() {
    let reg = CacheRegistry::new();
    let entries = vec![reg.entry(CacheKey::new(APP, gpu()))];
    let specs = [OptimizerSpec::named("random"), OptimizerSpec::named("sa")];
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    let ids = vec![entries[0].cache.id()];
    let (runs, seed) = (3usize, 13u64);
    let jobs = grid_jobs(&entries, &factories, runs, seed);
    assert_eq!(jobs.len(), 6);

    // Single-process reference report.
    let curves: Vec<Vec<f64>> = jobs.iter().map(|j| j.execute()).collect();
    let groups: Vec<usize> = jobs.iter().map(|j| j.group).collect();
    let grouped = collate_groups(labels.len(), &groups, curves);
    let results = grid_aggregates(&labels, 1, grouped);
    let summary = JobsSummary {
        completed: jobs.len(),
        cancelled: 0,
        failed: 0,
        cost_us: jobs.iter().map(|j| j.cost_us()).sum(),
    };
    let reference = scores_json("t", &ids, &results, &summary).to_pretty();

    // Uneven split: 6 jobs over 4 shards (2, 2, 1, 1 jobs).
    let count = 4;
    let partials: Vec<Json> = (0..count)
        .map(|k| {
            let shard = ShardSpec { index: k, count };
            let rows: Vec<ShardJob> = (0..jobs.len())
                .filter(|&i| shard.owns(i))
                .map(|i| ShardJob {
                    index: i,
                    group: jobs[i].group,
                    curve: jobs[i].execute(),
                })
                .collect();
            let summary = JobsSummary {
                completed: rows.len(),
                cancelled: 0,
                failed: 0,
                cost_us: (0..jobs.len())
                    .filter(|&i| shard.owns(i))
                    .map(|i| jobs[i].cost_us())
                    .sum(),
            };
            through_file(partial_coordinate_json(
                "t",
                &ids,
                &labels,
                runs,
                seed,
                &shard,
                jobs.len(),
                &summary,
                &rows,
            ))
        })
        .collect();

    let merged = merge_reports(&partials).unwrap();
    assert_eq!(merged.to_pretty(), reference, "merge must be byte-identical");
    // Including the jobs block: 2+2+1+1 = the single-process count.
    assert_eq!(
        merged.get("jobs").unwrap().get("completed").unwrap().as_usize(),
        Some(6)
    );
    // Order of partials must not matter.
    let reversed: Vec<Json> = partials.iter().rev().cloned().collect();
    assert_eq!(merge_reports(&reversed).unwrap().to_pretty(), reference);
}

/// GA with everything but `elites` pinned: a 4-point meta space.
fn ga_narrow() -> OptimizerSpec {
    OptimizerSpec::parse(
        "ga:population_size=8,tournament_k=2,crossover_rate=0.8,mutation_rate_factor=0.8",
    )
    .unwrap()
}

fn conv_entries() -> Vec<Arc<llamea_kt::coordinator::SpaceEntry>> {
    vec![CacheRegistry::global().entry(CacheKey::parse("convolution@A4000").unwrap())]
}

#[test]
fn sharded_sweep_merges_to_the_single_process_report() {
    let (runs, seed) = (2usize, 9u64);
    // Single-process grid sweep.
    let full_mt = MetaTuning::new(ga_narrow(), conv_entries(), runs, seed, Some(2)).unwrap();
    let outcome = sweep(&full_mt, &MetaStrategy::Grid, seed);
    let reference = sweep_json(&full_mt, &outcome, seed).to_pretty();

    // Uneven split: 4 meta-ordinals over 3 shards.
    let count = 3;
    let n = full_mt.space().len();
    let partials: Vec<Json> = (0..count)
        .map(|k| {
            let shard = ShardSpec { index: k, count };
            let mt = MetaTuning::new(ga_narrow(), conv_entries(), runs, seed, Some(2)).unwrap();
            let cands: Vec<u32> =
                (0..n as u32).filter(|&o| shard.owns(o as usize)).collect();
            mt.evaluate_all(&cands, mt.runs());
            let outcome = SweepOutcome {
                strategy: MetaStrategy::Grid.label(),
                leaderboard: mt.leaderboard(),
                rungs: Vec::new(),
            };
            through_file(sweep_partial_json(&mt, &outcome, seed, &shard))
        })
        .collect();

    let merged = merge_reports(&partials).unwrap();
    assert_eq!(merged.to_pretty(), reference, "sweep merge must be byte-identical");
    // Partials from a different sweep are refused.
    let other_mt =
        MetaTuning::new(ga_narrow(), conv_entries(), runs, seed + 1, Some(2)).unwrap();
    let other = SweepOutcome {
        strategy: MetaStrategy::Grid.label(),
        leaderboard: Vec::new(),
        rungs: Vec::new(),
    };
    let bad = through_file(sweep_partial_json(
        &other_mt,
        &other,
        seed + 1,
        &ShardSpec { index: 0, count },
    ));
    let mut mixed = partials.clone();
    mixed[0] = bad;
    assert!(merge_reports(&mixed).is_err());
}
