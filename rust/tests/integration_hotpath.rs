//! Integration: the precomputed hot path (PR 4).
//!
//! - CSR neighbor rows (`SearchSpace::neighbors_of`) must equal the
//!   pre-refactor on-the-fly enumeration element-for-element, for all
//!   four application spaces and all three `NeighborKind`s. The reference
//!   implementation below is the pre-CSR `SearchSpace::neighbors` code,
//!   ported verbatim so drift in the shared helper cannot mask a
//!   regression.
//! - Parallel space construction must be byte-identical to `--threads 1`
//!   construction (the enumeration-order contract every config ordinal,
//!   seed and golden result depends on).
//! - The CSR table must come out identical no matter which thread wins
//!   the `OnceLock` race (build under `std::thread::scope` contention vs
//!   a serial build).
//! - Compiled constraint programs must agree with the AST evaluator on
//!   arbitrary (also invalid) assignments.

use std::sync::Arc;

use llamea_kt::searchspace::{Application, NeighborKind, SearchSpace};
use llamea_kt::util::proptest::check;

/// Pre-refactor `SearchSpace::neighbors`, verbatim (hash probes over an
/// owned probe vector; StrictlyAdjacent = Adjacent then diagonals).
fn reference_neighbors(space: &SearchSpace, i: u32, kind: NeighborKind) -> Vec<u32> {
    let base = space.config(i).to_vec();
    let mut out = Vec::new();
    let mut probe = base.clone();
    let dims = space.dims();
    match kind {
        NeighborKind::Hamming => {
            for d in 0..dims {
                let orig = base[d];
                for vi in 0..space.params.params[d].cardinality() as u16 {
                    if vi == orig {
                        continue;
                    }
                    probe[d] = vi;
                    if let Some(j) = space.index_of(&probe) {
                        out.push(j);
                    }
                }
                probe[d] = orig;
            }
        }
        NeighborKind::Adjacent => {
            for d in 0..dims {
                let orig = base[d];
                let card = space.params.params[d].cardinality() as u16;
                if orig > 0 {
                    probe[d] = orig - 1;
                    if let Some(j) = space.index_of(&probe) {
                        out.push(j);
                    }
                }
                if orig + 1 < card {
                    probe[d] = orig + 1;
                    if let Some(j) = space.index_of(&probe) {
                        out.push(j);
                    }
                }
                probe[d] = orig;
            }
        }
        NeighborKind::StrictlyAdjacent => {
            out = reference_neighbors(space, i, NeighborKind::Adjacent);
            for d1 in 0..dims {
                for d2 in (d1 + 1)..dims {
                    for s1 in [-1i32, 1] {
                        for s2 in [-1i32, 1] {
                            let v1 = base[d1] as i32 + s1;
                            let v2 = base[d2] as i32 + s2;
                            if v1 < 0
                                || v2 < 0
                                || v1 >= space.params.params[d1].cardinality() as i32
                                || v2 >= space.params.params[d2].cardinality() as i32
                            {
                                continue;
                            }
                            probe[d1] = v1 as u16;
                            probe[d2] = v2 as u16;
                            if let Some(j) = space.index_of(&probe) {
                                out.push(j);
                            }
                            probe[d1] = base[d1];
                            probe[d2] = base[d2];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Property: sampled CSR rows equal the reference enumeration (plus the
/// first and last row, the concatenation seams of the chunked build).
fn csr_matches_reference(app: Application, cases: u64) {
    let space = app.build_space();
    for kind in NeighborKind::ALL {
        let last = space.len() as u32 - 1;
        for i in [0, last] {
            assert_eq!(
                space.neighbors_of(i, kind),
                reference_neighbors(&space, i, kind).as_slice(),
                "{} {:?} row {}",
                app.name(),
                kind,
                i
            );
        }
        check(&format!("csr {} {:?}", app.name(), kind), cases, |rng| {
            let i = rng.below(space.len()) as u32;
            assert_eq!(
                space.neighbors_of(i, kind),
                reference_neighbors(&space, i, kind).as_slice(),
                "{} {:?} row {}",
                app.name(),
                kind,
                i
            );
        });
    }
}

#[test]
fn csr_matches_reference_dedispersion() {
    csr_matches_reference(Application::Dedispersion, 400);
}

#[test]
fn csr_matches_reference_convolution() {
    csr_matches_reference(Application::Convolution, 400);
}

#[test]
fn csr_matches_reference_gemm() {
    csr_matches_reference(Application::Gemm, 250);
}

#[test]
fn csr_matches_reference_hotspot() {
    csr_matches_reference(Application::Hotspot, 150);
}

#[test]
fn parallel_space_build_byte_identical_to_serial() {
    for app in [Application::Dedispersion, Application::Convolution, Application::Gemm] {
        let base = app.build_space(); // process-default width
        let serial = SearchSpace::build_parsed_width(
            &base.name,
            base.params.clone(),
            base.constraints.clone(),
            1,
        );
        let wide = SearchSpace::build_parsed_width(
            &base.name,
            base.params.clone(),
            base.constraints.clone(),
            8,
        );
        assert_eq!(serial.len(), base.len(), "{}", app.name());
        assert_eq!(serial.len(), wide.len(), "{}", app.name());
        for i in serial.iter_indices() {
            assert_eq!(serial.config(i), wide.config(i), "{} config {}", app.name(), i);
            assert_eq!(serial.config(i), base.config(i), "{} config {}", app.name(), i);
        }
    }
}

#[test]
fn csr_rows_identical_regardless_of_building_thread() {
    // Serial reference: every row of every kind, built on this thread.
    let serial = Application::Convolution.build_space();
    for kind in NeighborKind::ALL {
        let _ = serial.neighbors_of(0, kind);
    }

    // Fresh space, tables raced by 8 threads under scope contention; the
    // OnceLock admits one winner per kind, and chunk-ordered assembly
    // makes every candidate table identical — so the surviving rows must
    // match the serial build exactly.
    let contended = Arc::new(Application::Convolution.build_space());
    std::thread::scope(|scope| {
        for t in 0..8usize {
            let sp = Arc::clone(&contended);
            scope.spawn(move || {
                for kind in NeighborKind::ALL {
                    let i = (t * 131) as u32 % sp.len() as u32;
                    let _ = sp.neighbors_of(i, kind);
                }
            });
        }
    });
    for kind in NeighborKind::ALL {
        for i in serial.iter_indices() {
            assert_eq!(
                contended.neighbors_of(i, kind),
                serial.neighbors_of(i, kind),
                "kind {:?} row {}",
                kind,
                i
            );
        }
    }
}

#[test]
fn compiled_constraints_match_ast_on_random_assignments() {
    for app in Application::ALL {
        let space = app.build_space();
        check(&format!("constraints {}", app.name()), 512, |rng| {
            // Arbitrary raw assignment — valid or not.
            let cfg: Vec<u16> = (0..space.dims())
                .map(|d| rng.below(space.params.params[d].cardinality()) as u16)
                .collect();
            let vals: Vec<f64> = cfg
                .iter()
                .enumerate()
                .map(|(d, &vi)| space.params.value_f64(d, vi))
                .collect();
            let mut stack = Vec::new();
            for c in &space.constraints {
                assert_eq!(
                    c.holds(&vals),
                    c.holds_scratch(&vals, &mut stack),
                    "{}: {}",
                    app.name(),
                    c.source
                );
            }
            let mut vbuf = Vec::new();
            assert_eq!(
                space.satisfies_constraints(&cfg),
                space.satisfies_constraints_scratch(&cfg, &mut vbuf, &mut stack),
                "{}",
                app.name()
            );
        });
    }
}
