//! End-to-end tests of the `serve` daemon over real TCP connections —
//! the master contract: a served coordinate report is **byte-identical**
//! to the direct CLI path's report for the same spec (modulo the
//! non-deterministic `"caches"` metadata block), for any pool width, any
//! number of concurrent sessions, and any cancellation timing of *other*
//! sessions. Every server here binds port 0 on localhost; the global
//! cache registry is shared across tests (entries are memoized, and the
//! `"caches"` block is stripped before every comparison).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use llamea_kt::coordinator::{
    coordinate_report, grid_jobs, CacheKey, CacheRegistry, Executor, SpaceEntry, COORDINATE_TITLE,
};
use llamea_kt::methodology::OptimizerFactory;
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::serve::{client, ServeConfig, Server, ServerHandle, SubmitSpec};
use llamea_kt::util::json::Json;

struct Daemon {
    addr: String,
    handle: ServerHandle,
    join: std::thread::JoinHandle<std::io::Result<()>>,
}

impl Daemon {
    fn start(config: ServeConfig) -> Daemon {
        let server = Server::bind("127.0.0.1:0", config).expect("bind ephemeral port");
        let addr = server.local_addr().to_string();
        let handle = server.handle();
        let join = std::thread::spawn(move || server.run());
        Daemon { addr, handle, join }
    }

    fn stop(self) {
        self.handle.shutdown();
        self.join.join().unwrap().expect("accept loop exits cleanly");
    }
}

/// The direct-CLI report for a coordinate spec: the exact assembly path
/// `llamea-kt coordinate --out` uses (borrowed grid through the
/// streaming executor, then [`coordinate_report`]), without the
/// `"caches"` block `write_report` appends.
fn direct_report(spaces: &[&str], opts: &[&str], runs: usize, seed: u64, width: usize) -> String {
    let registry = CacheRegistry::global();
    let entries: Vec<Arc<SpaceEntry>> =
        spaces.iter().map(|s| registry.entry(CacheKey::parse(s).unwrap())).collect();
    let specs: Vec<OptimizerSpec> =
        opts.iter().map(|o| OptimizerSpec::parse(o).unwrap()).collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let jobs = grid_jobs(&entries, &factories, runs, seed);
    let batch = Executor::with_threads(Some(width)).fail_fast().run_jobs(&jobs);
    let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch).to_string()
}

fn coordinate_spec(spaces: &[&str], opts: &[&str], runs: usize, seed: u64) -> SubmitSpec {
    SubmitSpec::Coordinate {
        spaces: spaces.iter().map(|s| s.to_string()).collect(),
        opts: opts.iter().map(|s| s.to_string()).collect(),
        runs,
        seed,
    }
}

/// Submit and return the served report with the `"caches"` block
/// stripped, serialized.
fn served_report(addr: &str, spec: &SubmitSpec) -> String {
    let (_, mut report) = client::submit(addr, spec, &mut |_| {}).expect("served report");
    report.remove("caches").expect("served reports carry a caches block");
    report.to_string()
}

#[test]
fn served_report_is_byte_identical_to_direct_at_widths_1_and_8() {
    let spaces = ["convolution@A4000"];
    let opts = ["sa", "random"];
    let reference = direct_report(&spaces, &opts, 3, 7, 2);
    for width in [1usize, 8] {
        let daemon =
            Daemon::start(ServeConfig { threads: Some(width), ..Default::default() });
        let served = served_report(&daemon.addr, &coordinate_spec(&spaces, &opts, 3, 7));
        assert_eq!(served, reference, "served bytes must not depend on pool width {}", width);
        daemon.stop();
    }
}

#[test]
fn concurrent_sessions_each_match_their_solo_runs() {
    let a = (["convolution@A4000"], ["sa", "random"], 3usize, 11u64);
    let b = (["convolution@W6600"], ["greedy_ils", "random"], 2usize, 23u64);
    let ref_a = direct_report(&a.0, &a.1, a.2, a.3, 2);
    let ref_b = direct_report(&b.0, &b.1, b.2, b.3, 2);
    let daemon = Daemon::start(ServeConfig { threads: Some(4), ..Default::default() });
    let (got_a, got_b) = std::thread::scope(|s| {
        let ta = s.spawn(|| served_report(&daemon.addr, &coordinate_spec(&a.0, &a.1, a.2, a.3)));
        let tb = s.spawn(|| served_report(&daemon.addr, &coordinate_spec(&b.0, &b.1, b.2, b.3)));
        (ta.join().unwrap(), tb.join().unwrap())
    });
    assert_eq!(got_a, ref_a, "session A must be isolated from concurrent session B");
    assert_eq!(got_b, ref_b, "session B must be isolated from concurrent session A");
    daemon.stop();
}

#[test]
fn cancelling_one_session_leaves_the_bystander_byte_identical() {
    let bystander = (["convolution@A4000"], ["sa", "random"], 3usize, 7u64);
    let reference = direct_report(&bystander.0, &bystander.1, bystander.2, bystander.3, 2);
    // Width 1 forces real interleaving and makes the victim's 20-job
    // grid long enough that a cancel sent at its second finished event
    // lands mid-run.
    let daemon = Daemon::start(ServeConfig { threads: Some(1), ..Default::default() });
    let (victim, bystander_got) = std::thread::scope(|s| {
        let tv = s.spawn(|| {
            let spec = coordinate_spec(&["convolution@W6600"], &["sa", "random"], 10, 5);
            let addr = daemon.addr.clone();
            let mut fired = false;
            let mut session_id = 0u64;
            let mut on_event = |ev: &Json| {
                if ev.get("event").and_then(|v| v.as_str()) == Some("accepted") {
                    session_id = ev.get("session").and_then(|v| v.as_usize()).unwrap() as u64;
                }
                if !fired
                    && ev.get("kind").and_then(|v| v.as_str()) == Some("finished")
                    && ev.get("completed").and_then(|v| v.as_usize()) == Some(2)
                {
                    fired = true;
                    client::cancel(&addr, session_id).expect("cancel reaches the daemon");
                }
            };
            client::submit(&daemon.addr, &spec, &mut on_event).expect("victim still gets a report")
        });
        let tb = s.spawn(|| {
            served_report(
                &daemon.addr,
                &coordinate_spec(&bystander.0, &bystander.1, bystander.2, bystander.3),
            )
        });
        (tv.join().unwrap(), tb.join().unwrap())
    });
    assert_eq!(
        bystander_got, reference,
        "cancelling another tenant must not perturb a bystander's bytes"
    );
    let (_, report) = victim;
    assert_eq!(
        report.get("interrupted"),
        Some(&Json::Bool(true)),
        "a mid-run cancel must mark the report interrupted: {}",
        report.to_string()
    );
    let jobs = report.get("jobs").expect("jobs block");
    let completed = jobs.get("completed").and_then(|v| v.as_usize()).unwrap();
    let cancelled = jobs.get("cancelled").and_then(|v| v.as_usize()).unwrap();
    let failed = jobs.get("failed").and_then(|v| v.as_usize()).unwrap();
    assert_eq!(completed + cancelled + failed, 20, "every admitted job gets an outcome");
    assert!(completed >= 2 && cancelled > 0, "completed-prefix: {}", jobs.to_string());
    daemon.stop();
}

#[test]
fn over_cap_submissions_are_rejected_with_diagnostics() {
    let daemon = Daemon::start(ServeConfig {
        threads: Some(1),
        queue_cap: 100,
        max_sessions: 1,
    });
    // Occupy the single session slot with a raw connection we control.
    let stream = TcpStream::connect(&daemon.addr).unwrap();
    let spec = coordinate_spec(&["convolution@A4000"], &["sa", "random"], 8, 3);
    let line = format!("{}\n", llamea_kt::serve::submit_request(&spec).to_string());
    (&stream).write_all(line.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut accepted = String::new();
    reader.read_line(&mut accepted).unwrap();
    assert!(accepted.contains(r#""event":"accepted""#), "{}", accepted);

    // Second session: rejected by the session cap, with a diagnostic.
    let err = client::submit(&daemon.addr, &coordinate_spec(&["convolution@A4000"], &["sa"], 1, 1), &mut |_| {})
        .expect_err("the session cap must reject a second session");
    assert!(err.contains("session limit reached"), "{}", err);
    assert!(err.contains("--max-sessions 1"), "{}", err);

    // A submission bigger than the queue cap is rejected regardless.
    let err = client::submit(&daemon.addr, &coordinate_spec(&["convolution@A4000"], &["sa", "random"], 51, 1), &mut |_| {})
        .expect_err("the queue cap must reject an oversized submission");
    assert!(err.contains("queue capacity exceeded"), "{}", err);

    // The occupant is untouched: drain it to its report.
    let mut saw_report = false;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap() > 0 {
        if line.contains(r#""event":"report""#) {
            saw_report = true;
            break;
        }
        line.clear();
    }
    assert!(saw_report, "the occupying session still completes");
    daemon.stop();
}

#[test]
fn malformed_and_truncated_lines_get_structured_errors_not_hangs() {
    let daemon = Daemon::start(ServeConfig { threads: Some(1), ..Default::default() });

    // Malformed JSON, unknown commands, and non-UTF-8 all answer with an
    // error event and keep the connection serving.
    let stream = TcpStream::connect(&daemon.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    for bad in ["{not json\n", "[]\n", "{\"cmd\":\"warp\"}\n"] {
        (&stream).write_all(bad.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains(r#""event":"error""#), "{:?} -> {}", bad, line);
    }
    (&stream).write_all(b"\xff\xfe\xfd\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("not UTF-8"), "{}", line);
    // ... and the same connection still answers a well-formed request.
    (&stream).write_all(b"{\"cmd\":\"status\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains(r#""event":"status""#), "{}", line);
    drop(reader);
    drop(stream);

    // A truncated final line (no newline before EOF) is still answered.
    let stream = TcpStream::connect(&daemon.addr).unwrap();
    (&stream).write_all(b"{\"cmd\":\"status\"}").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut response = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_to_string(&mut response).unwrap();
    assert!(response.contains(r#""event":"status""#), "{}", response);

    // An unterminated line past the 1 MiB cap is answered with an error,
    // never buffered unboundedly. Exactly cap+1 bytes, so the daemon
    // consumes everything we sent (clean close, no RST racing the
    // response).
    let stream = TcpStream::connect(&daemon.addr).unwrap();
    let oversized = vec![b'a'; llamea_kt::serve::MAX_LINE_BYTES + 1];
    (&stream).write_all(&oversized).unwrap();
    let mut response = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_to_string(&mut response).unwrap();
    assert!(response.contains("exceeds 1 MiB"), "{}", response);

    // Unknown-session control requests are diagnostics, not panics.
    let err = client::cancel(&daemon.addr, 999).expect_err("unknown session");
    assert!(err.contains("unknown session 999"), "{}", err);
    let err = client::tail(&daemon.addr, 999, &mut |_| {}).expect_err("unknown session");
    assert!(err.contains("unknown session 999"), "{}", err);

    daemon.stop();
}

#[test]
fn tail_replays_a_finished_session_report() {
    let daemon = Daemon::start(ServeConfig { threads: Some(2), ..Default::default() });
    let spec = coordinate_spec(&["convolution@A4000"], &["sa"], 2, 9);
    let (session, mut first) = client::submit(&daemon.addr, &spec, &mut |_| {}).unwrap();
    let mut tailed =
        client::tail(&daemon.addr, session, &mut |_| {}).expect("finished sessions replay");
    first.remove("caches").unwrap();
    tailed.remove("caches").unwrap();
    assert_eq!(
        first.to_string(),
        tailed.to_string(),
        "tail must replay the retained report byte-for-byte"
    );
    daemon.stop();
}
