//! Integration: the 24-cache evaluation set has the cross-space properties
//! the paper's experiments rely on.

use llamea_kt::searchspace::Application;
use llamea_kt::tuning::build_caches_for;

#[test]
fn full_training_set_builds_with_sane_statistics() {
    let caches = build_caches_for(&["A100", "A4000", "MI250X"]);
    assert_eq!(caches.len(), 12);
    for c in &caches {
        assert!(c.optimum_ms > 0.0, "{}", c.id());
        // Tuning must matter on every space.
        assert!(c.median_ms / c.optimum_ms > 1.3, "{}: spread too small", c.id());
        // Failures exist but are bounded.
        let failures = c.mean_ms.iter().filter(|t| !t.is_finite()).count();
        let rate = failures as f64 / c.len() as f64;
        assert!(rate < 0.15, "{}: failure rate {}", c.id(), rate);
    }
}

#[test]
fn optima_differ_across_gpus_for_same_kernel() {
    let caches = build_caches_for(&["A100", "W6600"]);
    for app in Application::ALL {
        let per_app: Vec<_> = caches.iter().filter(|c| c.app == app).collect();
        assert_eq!(per_app.len(), 2);
        let argmin = |c: &llamea_kt::tuning::Cache| -> usize {
            c.mean_ms
                .iter()
                .enumerate()
                .filter(|(_, t)| t.is_finite())
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        // Different hardware, same kernel: the optimum usually moves. We
        // require it for at least one runtime-scale difference instead of
        // exact config identity (which can coincide).
        let (a, b) = (per_app[0], per_app[1]);
        assert!(argmin(a) != argmin(b) || (a.optimum_ms / b.optimum_ms - 1.0).abs() > 0.05,
            "{}: suspiciously identical optima", app.name());
    }
}

#[test]
fn bandwidth_vs_compute_character() {
    // Paper §4.1.1: dedispersion/hotspot bandwidth-bound, conv/gemm
    // compute-bound. Check via the A100 vs A6000 ratio: A6000 has ~2x the
    // fp32 but half the bandwidth of A100, so compute-bound kernels should
    // do *relatively* better on A6000 than bandwidth-bound ones.
    let caches = build_caches_for(&["A100", "A6000"]);
    let optimum = |app: Application, gpu: &str| -> f64 {
        caches
            .iter()
            .find(|c| c.app == app && c.gpu.name == gpu)
            .unwrap()
            .optimum_ms
    };
    let rel = |app: Application| optimum(app, "A6000") / optimum(app, "A100");
    // Lower = A6000 relatively better.
    assert!(rel(Application::Gemm) < rel(Application::Dedispersion),
        "gemm {} dedisp {}", rel(Application::Gemm), rel(Application::Dedispersion));
}
