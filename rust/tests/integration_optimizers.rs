//! Integration: every registered optimizer, on every application, behaves
//! within the API contract and produces finite results.

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::SpaceSetup;
use llamea_kt::optimizers::{all_names, by_name};
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::{Cache, TuningContext};

#[test]
fn all_optimizers_on_all_apps_terminate_with_finite_best() {
    for app in [Application::Dedispersion, Application::Convolution, Application::Gemm] {
        let cache = Cache::build(app, GpuSpec::by_name("A4000").unwrap());
        let setup = SpaceSetup::new(&cache);
        let budget = setup.budget_s.min(500.0);
        for name in all_names() {
            let mut opt = by_name(name).unwrap();
            let mut ctx = TuningContext::new(&cache, budget, 11);
            opt.run(&mut ctx);
            let (_, best) = ctx.best().unwrap_or((0, f64::NAN));
            assert!(best.is_finite(), "{} on {}", name, app.name());
            assert!(ctx.elapsed_s() >= budget * 0.95, "{} quit early", name);
        }
    }
}

#[test]
fn generated_algorithms_beat_human_baselines_on_aggregate() {
    // The paper's headline claim, on a reduced slice: 2 generated vs 3
    // human-designed over 8 spaces x 15 runs.
    use llamea_kt::methodology::{evaluate_all, NamedFactory, OptimizerFactory};
    let caches = llamea_kt::tuning::build_caches_for(&["A4000", "W6600"]);
    let names = ["hybrid_vndx", "atgw", "ga", "sa", "de"];
    let factories: Vec<NamedFactory> = names.iter().map(|n| NamedFactory(n.to_string())).collect();
    let refs: Vec<&dyn OptimizerFactory> = factories.iter().map(|f| f as _).collect();
    let results = evaluate_all(&caches, &refs, 15, 77);
    let score = |n: &str| results.iter().find(|(l, _)| l == n).unwrap().1.score;
    let avg_gen = (score("hybrid_vndx") + score("atgw")) / 2.0;
    let avg_human = (score("ga") + score("sa") + score("de")) / 3.0;
    assert!(
        avg_gen > avg_human,
        "generated {:.3} vs human {:.3}",
        avg_gen,
        avg_human
    );
}
