//! Integration: the L3 coordinator — shared cache registry, execution-API
//! determinism (streamed sources, priorities, cancellation, panic
//! isolation, backpressure), and per-job seed derivation.
//!
//! Width-sensitive checks use `util::parallel::test_width` (the
//! `LLAMEA_KT_TEST_THREADS` knob) so CI's width matrix exercises them at
//! 1 and 8 workers.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use llamea_kt::coordinator::{
    collate, collate_groups, grid_aggregates, grid_jobs, grid_source, job_seed, BatchResult,
    CacheKey, CacheRegistry, Executor, FnSource, JobOutcome, JobSource, Progress, Scheduler,
    SourcedJob, TuningJob,
};
use llamea_kt::methodology::{run_many, OptimizerFactory};
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::util::parallel::test_width;

fn test_factories(names: &[&str]) -> Vec<(String, OptimizerSpec)> {
    names.iter().map(|n| (n.to_string(), OptimizerSpec::named(*n))).collect()
}

fn as_refs(owned: &[(String, OptimizerSpec)]) -> Vec<(String, &dyn OptimizerFactory)> {
    owned.iter().map(|(l, s)| (l.clone(), s as &dyn OptimizerFactory)).collect()
}

/// The acceptance property: scheduler output is byte-identical across
/// thread counts, on a grid spanning spaces AND optimizers AND seeds.
#[test]
fn grid_output_identical_across_thread_counts() {
    let reg = CacheRegistry::new();
    let entries = vec![
        reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
        reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
    ];
    let owned = test_factories(&["random", "sa"]);
    let factories = as_refs(&owned);
    let jobs = grid_jobs(&entries, &factories, 4, 2026);
    assert_eq!(jobs.len(), 2 * 2 * 4);
    let single = Scheduler::new(1).run(&jobs);
    let wide = Scheduler::new(test_width(8)).run(&jobs);
    assert_eq!(single, wide, "thread count changed results");

    // And the aggregates reassemble per (optimizer, space) without loss.
    let grouped = collate(factories.len() * entries.len(), &jobs, wide);
    assert!(grouped.iter().all(|g| g.len() == 4));
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    let results = grid_aggregates(&labels, entries.len(), grouped);
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|(_, a)| a.score.is_finite() && a.per_space_scores.len() == 2));
}

/// `run_many` (the single-space wrapper) must agree bit-for-bit with the
/// same runs executed inside a larger flat batch — the property that lets
/// the harness swap per-experiment loops for one job graph.
#[test]
fn run_many_matches_flat_batch_execution() {
    let reg = CacheRegistry::new();
    let e = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
    let owned = test_factories(&["sa", "random"]);
    let factories = as_refs(&owned);
    let entries = vec![e.clone()];
    let jobs = grid_jobs(&entries, &factories, 5, 99);
    let grouped = collate(factories.len(), &jobs, Scheduler::auto().run(&jobs));
    let via_wrapper_sa = run_many(&e.cache, &e.setup, &owned[0].1, 5, 99);
    let via_wrapper_random = run_many(&e.cache, &e.setup, &owned[1].1, 5, 99);
    assert_eq!(grouped[0], via_wrapper_sa);
    assert_eq!(grouped[1], via_wrapper_random);
}

/// The registry builds each (application, GPU) cache at most once under
/// concurrent access from many scheduler-like workers.
#[test]
fn registry_builds_once_under_concurrent_grid_access() {
    let reg = CacheRegistry::new();
    let keys = [
        CacheKey::parse("convolution@A4000").unwrap(),
        CacheKey::parse("convolution@W6600").unwrap(),
    ];
    std::thread::scope(|scope| {
        for t in 0..8 {
            let keys = &keys;
            let reg = &reg;
            scope.spawn(move || {
                for _ in 0..4 {
                    let e = reg.entry(keys[t % keys.len()]);
                    assert!(e.cache.len() > 0);
                    assert!(e.setup.budget_s > 0.0);
                }
            });
        }
    });
    assert_eq!(reg.builds(), keys.len(), "each key must build exactly once");
    // One application, two GPUs: the enumerated space is also shared.
    assert_eq!(reg.space_builds(), 1);
}

/// The acceptance property for `experiment all`: every harness entry point
/// shares the process-wide registry, so re-running an evaluation builds
/// zero new caches.
#[test]
fn global_registry_is_shared_across_harness_calls() {
    let out = std::env::temp_dir().join("llamea_kt_coord_test");
    let opts = llamea_kt::harness::ExpOptions {
        runs: 1,
        gen_runs: 1,
        llm_calls: 4,
        seed: 3,
        ..Default::default()
    };
    let owned = test_factories(&["random"]);
    let factories = as_refs(&owned);
    let first =
        llamea_kt::harness::experiments::evaluate_on_all_spaces(&factories, &opts, 3, &out, "t1");
    assert_eq!(first[0].2.len(), 24, "4 applications x 6 GPUs");
    let after_first = CacheRegistry::global().builds();
    assert!(after_first <= 24, "at most one build per (app, GPU): {}", after_first);
    let second =
        llamea_kt::harness::experiments::evaluate_on_all_spaces(&factories, &opts, 3, &out, "t2");
    assert_eq!(
        CacheRegistry::global().builds(),
        after_first,
        "second harness call must not rebuild caches"
    );
    // Same seeds, same registry: identical scores.
    assert_eq!(first[0].1.per_space_scores, second[0].1.per_space_scores);
}

// ------------------------------------------------ execution API (PR 5)

/// One (space, spec) fixture over the shared registry.
fn exec_fixture() -> (std::sync::Arc<llamea_kt::coordinator::SpaceEntry>, OptimizerSpec, String) {
    let e = CacheRegistry::global().entry(CacheKey::parse("convolution@A4000").unwrap());
    let space_id = e.cache.id();
    (e, OptimizerSpec::named("sa"), space_id)
}

fn seeded_jobs<'a>(
    e: &'a llamea_kt::coordinator::SpaceEntry,
    spec: &'a OptimizerSpec,
    space_id: &str,
    n: usize,
    base: u64,
) -> Vec<TuningJob<'a>> {
    (0..n)
        .map(|r| TuningJob {
            source: &e.cache,
            setup: &e.setup,
            factory: spec,
            seed: job_seed(base, space_id, "sa", r as u64),
            group: 0,
        })
        .collect()
}

/// Verbatim port of the pre-redesign `Scheduler::run` (atomic cursor over
/// a materialized batch, `OnceLock` result slots): the golden reference
/// for the executor's drain-all equivalence — the acceptance criterion
/// that the redesign changed the engine, not one bit of the results.
fn pre_redesign_scheduler_run(jobs: &[TuningJob], threads: usize) -> Vec<Vec<f64>> {
    use std::sync::OnceLock;
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        return jobs.iter().map(TuningJob::execute).collect();
    }
    let slots: Vec<OnceLock<Vec<f64>>> = (0..n).map(|_| OnceLock::new()).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= n {
                    break;
                }
                let curve = jobs[j].execute();
                slots[j].set(curve).expect("job slot written twice");
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().expect("scheduler finished with a missing result"))
        .collect()
}

#[test]
fn executor_is_bit_identical_to_the_pre_redesign_scheduler() {
    let reg = CacheRegistry::new();
    let entries = vec![
        reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
        reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
    ];
    let owned = test_factories(&["sa", "random"]);
    let factories = as_refs(&owned);
    let jobs = grid_jobs(&entries, &factories, 3, 31);
    let width = test_width(8);
    let old = pre_redesign_scheduler_run(&jobs, width);
    // The compatibility wrapper, the executor batch API, and the lazy
    // streamed grid must all reproduce the pre-redesign output exactly.
    assert_eq!(old, Scheduler::new(width).run(&jobs));
    assert_eq!(old, Executor::new(width).run_jobs(&jobs).expect_curves());
    let mut streamed = grid_source(&entries, &factories, 3, 31);
    let batch = Executor::new(width).queue_cap(3).run(&mut streamed);
    assert_eq!(batch.groups(), jobs.iter().map(|j| j.group).collect::<Vec<_>>());
    assert_eq!(old, batch.expect_curves());
}

#[test]
fn completed_prefix_is_bit_identical_under_mid_batch_cancellation() {
    let (e, spec, space_id) = exec_fixture();
    let jobs = seeded_jobs(&e, &spec, &space_id, 8, 5);
    let reference = Executor::new(1).run_jobs(&jobs).expect_curves();

    // Deterministic single-worker run, default lookahead (2): cancel after
    // the 3rd completion. Jobs 0–2 completed, the one queued job (3)
    // cancelled, jobs 4+ never pulled.
    let exec = Executor::new(1);
    let token = exec.cancel_token();
    let sink = |ev: &Progress| {
        if let Progress::Finished { completed: 3, .. } = ev {
            token.cancel();
        }
    };
    let batch = exec.run_jobs_observed(&jobs, &sink);
    assert_eq!(batch.len(), 4, "one queued job beyond the completed prefix");
    for h in &batch.handles[..3] {
        assert_eq!(
            h.outcome.curve().expect("prefix job completed"),
            &reference[h.slot][..],
            "completed slot {} must be bit-identical to the drain-all run",
            h.slot
        );
    }
    assert_eq!(batch.handles[3].outcome, JobOutcome::Cancelled);
    let s = batch.summary();
    assert_eq!((s.completed, s.cancelled, s.failed), (3, 1, 0));
}

#[test]
fn cancellation_under_contention_preserves_every_completed_curve() {
    // Wide variant: whichever jobs complete under a racing cancellation,
    // each completed curve is exactly its drain-all counterpart, and the
    // batch can never complete fully (40 jobs >> the lookahead window).
    let (e, spec, space_id) = exec_fixture();
    let jobs = seeded_jobs(&e, &spec, &space_id, 40, 6);
    let reference = Executor::new(1).run_jobs(&jobs).expect_curves();
    let exec = Executor::new(test_width(8));
    let token = exec.cancel_token();
    let sink = |ev: &Progress| {
        if let Progress::Finished { completed: 2, .. } = ev {
            token.cancel();
        }
    };
    let batch = exec.run_jobs_observed(&jobs, &sink);
    let s = batch.summary();
    assert!(s.completed >= 2, "the two triggering completions are in the batch");
    assert!(
        s.completed < jobs.len(),
        "cancellation must stop the batch short ({} completed)",
        s.completed
    );
    for h in &batch.handles {
        if let Some(curve) = h.outcome.curve() {
            assert_eq!(curve, &reference[h.slot][..], "slot {}", h.slot);
        }
    }
}

#[test]
fn results_are_invariant_to_priority_order() {
    let (e, spec, space_id) = exec_fixture();
    let jobs = seeded_jobs(&e, &spec, &space_id, 6, 77);
    let run_with = |priorities: fn(usize) -> i64| -> (Vec<Vec<f64>>, Vec<usize>) {
        let started = Mutex::new(Vec::new());
        let sink = |ev: &Progress| {
            if let Progress::Started { slot } = ev {
                started.lock().unwrap().push(*slot);
            }
        };
        let mut source =
            FnSource::new(jobs.len(), |i| SourcedJob { job: jobs[i], priority: priorities(i) });
        // Width 1 with a whole-batch window: execution order is exactly
        // the priority order, results must not care.
        let batch = Executor::new(1).queue_cap(jobs.len()).run_observed(&mut source, &sink);
        (batch.expect_curves(), started.into_inner().unwrap())
    };
    let (flat, order_flat) = run_with(|_| 0);
    let (ascending, order_asc) = run_with(|i| i as i64);
    let (wide, _) = {
        let mut source =
            FnSource::new(jobs.len(), |i| SourcedJob { job: jobs[i], priority: -(i as i64) });
        let batch = Executor::new(test_width(4)).run(&mut source);
        (batch.expect_curves(), ())
    };
    assert_eq!(flat, ascending, "priorities reordered results");
    assert_eq!(flat, wide, "priorities reordered results under contention");
    // And priorities really do steer execution: equal priorities run in
    // slot order, ascending priorities in reverse slot order.
    assert_eq!(order_flat, (0..jobs.len()).collect::<Vec<_>>());
    assert_eq!(order_asc, (0..jobs.len()).rev().collect::<Vec<_>>());
}

/// A [`JobSource`] that records how far ahead of completion it has been
/// polled (the backpressure observable).
struct CountingSource<'a> {
    jobs: &'a [TuningJob<'a>],
    next: usize,
    finished: &'a AtomicUsize,
    max_lead: &'a AtomicUsize,
}

impl<'a> JobSource<'a> for CountingSource<'a> {
    fn next_job(&mut self) -> Option<SourcedJob<'a>> {
        if self.next >= self.jobs.len() {
            return None;
        }
        let job = self.jobs[self.next];
        self.next += 1;
        let lead = self.next - self.finished.load(Ordering::SeqCst).min(self.next);
        self.max_lead.fetch_max(lead, Ordering::SeqCst);
        Some(job.into())
    }
}

#[test]
fn source_is_polled_at_most_queue_cap_ahead() {
    let (e, spec, space_id) = exec_fixture();
    let jobs = seeded_jobs(&e, &spec, &space_id, 12, 13);
    let reference = Executor::new(1).run_jobs(&jobs).expect_curves();

    let run_bounded = |threads: usize, cap: usize| -> (Vec<Vec<f64>>, usize) {
        let finished = AtomicUsize::new(0);
        let max_lead = AtomicUsize::new(0);
        let mut source =
            CountingSource { jobs: &jobs, next: 0, finished: &finished, max_lead: &max_lead };
        let sink = |ev: &Progress| {
            if !matches!(ev, Progress::Started { .. }) {
                finished.fetch_add(1, Ordering::SeqCst);
            }
        };
        let batch = Executor::new(threads).queue_cap(cap).run_observed(&mut source, &sink);
        (batch.expect_curves(), max_lead.load(Ordering::SeqCst))
    };

    // Single worker: completions are observed synchronously, so the bound
    // is exact — and reached (the initial refill fills the window).
    let (curves, lead) = run_bounded(1, 3);
    assert_eq!(curves, reference);
    assert_eq!(lead, 3, "single-worker lead must equal queue_cap exactly");

    // Contended: the sink observes completions slightly after the pool's
    // internal counter, so allow one in-flight job per worker of lag.
    let threads = test_width(4);
    let (curves, lead) = run_bounded(threads, 4);
    assert_eq!(curves, reference);
    assert!(
        lead <= 4 + threads,
        "lead {} exceeds queue_cap 4 + {} workers of event lag",
        lead,
        threads
    );
}

/// The satellite regression: pre-redesign, one panicking
/// `TuningJob::execute` inside `thread::scope` aborted the whole batch
/// and lost every completed slot. The executor isolates it per job.
struct PanickingOpt;

impl llamea_kt::optimizers::Optimizer for PanickingOpt {
    fn name(&self) -> &str {
        "panicking"
    }
    fn run(&mut self, _ctx: &mut llamea_kt::tuning::TuningContext) {
        panic!("boom from the panicking test optimizer");
    }
}

struct PanickingFactory;

impl OptimizerFactory for PanickingFactory {
    fn build(&self) -> Box<dyn llamea_kt::optimizers::Optimizer> {
        Box::new(PanickingOpt)
    }
    fn label(&self) -> String {
        "panicking".into()
    }
}

#[test]
fn panicking_job_is_isolated_and_the_batch_keeps_its_results() {
    let (e, spec, space_id) = exec_fixture();
    let bomb = PanickingFactory;
    let mut jobs = seeded_jobs(&e, &spec, &space_id, 5, 21);
    let reference = Executor::new(1).run_jobs(&jobs).expect_curves();
    jobs[2].factory = &bomb;

    let batch = Executor::new(test_width(4)).run_jobs(&jobs);
    let s = batch.summary();
    assert_eq!((s.completed, s.cancelled, s.failed), (4, 0, 1));
    match &batch.handles[2].outcome {
        JobOutcome::Failed(msg) => {
            assert!(msg.contains("boom from the panicking test optimizer"), "{}", msg)
        }
        other => panic!("expected Failed, got {:?}", other),
    }
    for h in batch.handles.iter().filter(|h| h.slot != 2) {
        assert_eq!(
            h.outcome.curve().expect("non-panicking jobs complete"),
            &reference[h.slot][..],
            "slot {} lost or changed by the neighboring panic",
            h.slot
        );
    }
    // Collation over the survivors still works from the handles.
    let completed: Vec<(usize, Vec<f64>)> = batch
        .handles
        .iter()
        .filter_map(|h| h.outcome.curve().map(|c| (h.group, c.to_vec())))
        .collect();
    let groups: Vec<usize> = completed.iter().map(|(g, _)| *g).collect();
    let curves: Vec<Vec<f64>> = completed.into_iter().map(|(_, c)| c).collect();
    let grouped = collate_groups(1, &groups, curves);
    assert_eq!(grouped[0].len(), 4);
}

#[test]
#[should_panic(expected = "failed")]
fn drain_all_compat_surface_panics_on_failed_jobs() {
    // `Scheduler::run` keeps drain-all semantics: a failed job panics at
    // collection (with the structured per-job message) because the
    // curves-only API has no channel for partial results.
    let (e, spec, space_id) = exec_fixture();
    let bomb = PanickingFactory;
    let mut jobs = seeded_jobs(&e, &spec, &space_id, 3, 22);
    jobs[1].factory = &bomb;
    let _ = Scheduler::new(2).run(&jobs);
}

#[test]
fn batch_result_reports_slot_metadata() {
    let (e, spec, space_id) = exec_fixture();
    let jobs = seeded_jobs(&e, &spec, &space_id, 3, 23);
    let batch: BatchResult = Executor::new(2).run_jobs(&jobs);
    assert_eq!(batch.len(), 3);
    assert!(!batch.is_empty());
    for (h, job) in batch.handles.iter().zip(&jobs) {
        assert_eq!(h.seed, job.seed);
        assert_eq!(h.group, job.group);
        assert_eq!(h.priority, 0);
        assert!(h.outcome.is_completed());
    }
}

/// Property (mini-proptest): per-job seed derivation has no collisions
/// across a full 10k-job experiment grid, for arbitrary base seeds.
#[test]
fn job_seed_collision_free_over_10k_grid() {
    let apps = ["gemm", "convolution", "hotspot", "dedispersion"];
    let gpus = ["MI250X", "A100", "A4000", "W6600", "W7800", "A6000"];
    let opts: Vec<&str> = llamea_kt::optimizers::all_names().collect();
    llamea_kt::util::proptest::check("job seeds collision-free", 4, |rng| {
        let base = rng.next_u64();
        let mut seen = HashSet::new();
        let mut jobs = 0u64;
        for app in apps {
            for gpu in gpus {
                let sid = format!("{}@{}", app, gpu);
                for opt in &opts {
                    for run in 0..42u64 {
                        jobs += 1;
                        assert!(
                            seen.insert(job_seed(base, &sid, opt, run)),
                            "seed collision at {}/{}/run{} (base {:#x})",
                            sid,
                            opt,
                            run,
                            base
                        );
                    }
                }
            }
        }
        assert!(jobs > 10_000, "grid too small: {}", jobs);
    });
}
