//! Integration: the L3 coordinator — shared cache registry, job-graph
//! scheduler determinism, and per-job seed derivation.

use std::collections::HashSet;

use llamea_kt::coordinator::{
    collate, grid_aggregates, grid_jobs, job_seed, CacheKey, CacheRegistry, Scheduler,
};
use llamea_kt::methodology::{run_many, OptimizerFactory};
use llamea_kt::optimizers::OptimizerSpec;

fn test_factories(names: &[&str]) -> Vec<(String, OptimizerSpec)> {
    names.iter().map(|n| (n.to_string(), OptimizerSpec::named(*n))).collect()
}

fn as_refs(owned: &[(String, OptimizerSpec)]) -> Vec<(String, &dyn OptimizerFactory)> {
    owned.iter().map(|(l, s)| (l.clone(), s as &dyn OptimizerFactory)).collect()
}

/// The acceptance property: scheduler output is byte-identical across
/// thread counts, on a grid spanning spaces AND optimizers AND seeds.
#[test]
fn grid_output_identical_across_thread_counts() {
    let reg = CacheRegistry::new();
    let entries = vec![
        reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
        reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
    ];
    let owned = test_factories(&["random", "sa"]);
    let factories = as_refs(&owned);
    let jobs = grid_jobs(&entries, &factories, 4, 2026);
    assert_eq!(jobs.len(), 2 * 2 * 4);
    let single = Scheduler::new(1).run(&jobs);
    let wide = Scheduler::new(8).run(&jobs);
    assert_eq!(single, wide, "thread count changed results");

    // And the aggregates reassemble per (optimizer, space) without loss.
    let grouped = collate(factories.len() * entries.len(), &jobs, wide);
    assert!(grouped.iter().all(|g| g.len() == 4));
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    let results = grid_aggregates(&labels, entries.len(), grouped);
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|(_, a)| a.score.is_finite() && a.per_space_scores.len() == 2));
}

/// `run_many` (the single-space wrapper) must agree bit-for-bit with the
/// same runs executed inside a larger flat batch — the property that lets
/// the harness swap per-experiment loops for one job graph.
#[test]
fn run_many_matches_flat_batch_execution() {
    let reg = CacheRegistry::new();
    let e = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
    let owned = test_factories(&["sa", "random"]);
    let factories = as_refs(&owned);
    let entries = vec![e.clone()];
    let jobs = grid_jobs(&entries, &factories, 5, 99);
    let grouped = collate(factories.len(), &jobs, Scheduler::auto().run(&jobs));
    let via_wrapper_sa = run_many(&e.cache, &e.setup, &owned[0].1, 5, 99);
    let via_wrapper_random = run_many(&e.cache, &e.setup, &owned[1].1, 5, 99);
    assert_eq!(grouped[0], via_wrapper_sa);
    assert_eq!(grouped[1], via_wrapper_random);
}

/// The registry builds each (application, GPU) cache at most once under
/// concurrent access from many scheduler-like workers.
#[test]
fn registry_builds_once_under_concurrent_grid_access() {
    let reg = CacheRegistry::new();
    let keys = [
        CacheKey::parse("convolution@A4000").unwrap(),
        CacheKey::parse("convolution@W6600").unwrap(),
    ];
    std::thread::scope(|scope| {
        for t in 0..8 {
            let keys = &keys;
            let reg = &reg;
            scope.spawn(move || {
                for _ in 0..4 {
                    let e = reg.entry(keys[t % keys.len()]);
                    assert!(e.cache.len() > 0);
                    assert!(e.setup.budget_s > 0.0);
                }
            });
        }
    });
    assert_eq!(reg.builds(), keys.len(), "each key must build exactly once");
    // One application, two GPUs: the enumerated space is also shared.
    assert_eq!(reg.space_builds(), 1);
}

/// The acceptance property for `experiment all`: every harness entry point
/// shares the process-wide registry, so re-running an evaluation builds
/// zero new caches.
#[test]
fn global_registry_is_shared_across_harness_calls() {
    let out = std::env::temp_dir().join("llamea_kt_coord_test");
    let opts = llamea_kt::harness::ExpOptions {
        runs: 1,
        gen_runs: 1,
        llm_calls: 4,
        seed: 3,
        ..Default::default()
    };
    let owned = test_factories(&["random"]);
    let factories = as_refs(&owned);
    let first =
        llamea_kt::harness::experiments::evaluate_on_all_spaces(&factories, &opts, 3, &out, "t1");
    assert_eq!(first[0].2.len(), 24, "4 applications x 6 GPUs");
    let after_first = CacheRegistry::global().builds();
    assert!(after_first <= 24, "at most one build per (app, GPU): {}", after_first);
    let second =
        llamea_kt::harness::experiments::evaluate_on_all_spaces(&factories, &opts, 3, &out, "t2");
    assert_eq!(
        CacheRegistry::global().builds(),
        after_first,
        "second harness call must not rebuild caches"
    );
    // Same seeds, same registry: identical scores.
    assert_eq!(first[0].1.per_space_scores, second[0].1.per_space_scores);
}

/// Property (mini-proptest): per-job seed derivation has no collisions
/// across a full 10k-job experiment grid, for arbitrary base seeds.
#[test]
fn job_seed_collision_free_over_10k_grid() {
    let apps = ["gemm", "convolution", "hotspot", "dedispersion"];
    let gpus = ["MI250X", "A100", "A4000", "W6600", "W7800", "A6000"];
    let opts: Vec<&str> = llamea_kt::optimizers::all_names().collect();
    llamea_kt::util::proptest::check("job seeds collision-free", 4, |rng| {
        let base = rng.next_u64();
        let mut seen = HashSet::new();
        let mut jobs = 0u64;
        for app in apps {
            for gpu in gpus {
                let sid = format!("{}@{}", app, gpu);
                for opt in &opts {
                    for run in 0..42u64 {
                        jobs += 1;
                        assert!(
                            seen.insert(job_seed(base, &sid, opt, run)),
                            "seed collision at {}/{}/run{} (base {:#x})",
                            sid,
                            opt,
                            run,
                            base
                        );
                    }
                }
            }
        }
        assert!(jobs > 10_000, "grid too small: {}", jobs);
    });
}
