//! Integration: search-space construction against Table 1 and the
//! neighbor/repair API contracts used by optimizers.

use llamea_kt::searchspace::{Application, NeighborKind};
use llamea_kt::util::rng::Rng;

#[test]
fn table1_constrained_sizes_within_25pct_of_paper() {
    for app in Application::ALL {
        let (_, paper_constrained, _) = app.paper_table1();
        let space = app.build_space();
        let rel = (space.len() as f64 - paper_constrained as f64).abs()
            / paper_constrained as f64;
        assert!(
            rel < 0.25,
            "{}: ours {} vs paper {} ({:.1}%)",
            app.name(),
            space.len(),
            paper_constrained,
            rel * 100.0
        );
    }
}

#[test]
fn neighbor_api_contract_all_apps() {
    let mut rng = Rng::new(3);
    for app in Application::ALL {
        let space = app.build_space();
        for _ in 0..25 {
            let i = space.random_valid(&mut rng);
            for kind in [NeighborKind::Hamming, NeighborKind::Adjacent] {
                for j in space.neighbors(i, kind) {
                    assert_eq!(space.hamming(i, j), 1, "{}", app.name());
                    assert!(space.satisfies_constraints(space.config(j)));
                }
            }
        }
    }
}

#[test]
fn repair_always_returns_valid_all_apps() {
    let mut rng = Rng::new(5);
    for app in Application::ALL {
        let space = app.build_space();
        for _ in 0..50 {
            // Arbitrary (likely invalid) raw assignment.
            let cfg: Vec<u16> = (0..space.dims())
                .map(|d| rng.below(space.params.params[d].cardinality()) as u16)
                .collect();
            let i = space.repair(&cfg, &mut rng);
            assert!(space.satisfies_constraints(space.config(i)), "{}", app.name());
        }
    }
}
