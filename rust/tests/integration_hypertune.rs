//! Integration tests for the hypertune sweep seam (ISSUE 3 acceptance):
//!
//! - byte-identical sweep output for any scheduler width (the nested
//!   fan-out determinism contract);
//! - the golden equivalence: a grid-of-one sweep (every hyperparameter
//!   pinned on the base spec) reproduces a plain `coordinate`-style grid
//!   run of the same spec bit-for-bit;
//! - successive-halving rung survivors are invariant to candidate/job
//!   ordering;
//! - grid, random, successive-halving and a registry optimizer all run as
//!   meta-strategies, over two tuned optimizers (GA and SA).

use std::sync::Arc;

use llamea_kt::coordinator::{
    collate, grid_aggregates, grid_jobs, CacheKey, CacheRegistry, Scheduler, SpaceEntry,
};
use llamea_kt::hypertune::{
    successive_halving, sweep, sweep_json, meta_seed, MetaStrategy, MetaTuning,
};
use llamea_kt::methodology::OptimizerFactory;
use llamea_kt::optimizers::OptimizerSpec;

fn conv_entries() -> Vec<Arc<SpaceEntry>> {
    vec![CacheRegistry::global().entry(CacheKey::parse("convolution@A4000").unwrap())]
}

/// GA with everything but `elites` pinned: a 4-point meta space keeps the
/// inner grids small.
fn ga_narrow() -> OptimizerSpec {
    OptimizerSpec::parse(
        "ga:population_size=8,tournament_k=2,crossover_rate=0.8,mutation_rate_factor=0.8",
    )
    .unwrap()
}

/// SA with everything but `t0` pinned.
fn sa_narrow() -> OptimizerSpec {
    OptimizerSpec::parse("sa:alpha=0.99,t_min=0.0001,stagnation_limit=50").unwrap()
}

fn mt_with(base: OptimizerSpec, runs: usize, seed: u64, threads: usize) -> MetaTuning {
    MetaTuning::new(base, conv_entries(), runs, seed, Some(threads)).unwrap()
}

#[test]
fn sweep_output_is_byte_identical_across_thread_widths() {
    // The acceptance bar: the full sweep report — leaderboard, scores,
    // rung trace — serialized to JSON must not depend on scheduler width.
    for strategy in [
        MetaStrategy::Grid,
        MetaStrategy::Sha { eta: 2, evals: 4 },
        MetaStrategy::Search { spec: OptimizerSpec::parse("random").unwrap(), evals: 3 },
    ] {
        let narrow = mt_with(ga_narrow(), 2, 9, 1);
        let wide = mt_with(ga_narrow(), 2, 9, llamea_kt::util::parallel::test_width(8));
        let a = sweep_json(&narrow, &sweep(&narrow, &strategy, 9), 9).to_pretty();
        let b = sweep_json(&wide, &sweep(&wide, &strategy, 9), 9).to_pretty();
        assert_eq!(a, b, "strategy {} output depends on thread width", strategy.label());
        assert!(a.contains("\"leaderboard\""));
    }
}

#[test]
fn grid_of_one_sweep_equals_coordinate_run() {
    // Pin every GA hyperparameter at its tuned default: the meta space is
    // a single sentinel configuration, and the sweep must issue exactly
    // the jobs `coordinate --opts <spec> --spaces convolution@A4000` would
    // issue — same seeds (meta_seed(s, 0) == s), same label, same grid —
    // so the scores agree bit-for-bit.
    let spec = OptimizerSpec::parse(
        "ga:population_size=20,tournament_k=3,crossover_rate=0.9,mutation_rate_factor=1.2,elites=2",
    )
    .unwrap();
    let (runs, seed) = (3usize, 42u64);
    assert_eq!(meta_seed(seed, 0), seed);

    let mt = mt_with(spec.clone(), runs, seed, 4);
    assert_eq!(mt.space().len(), 1, "fully pinned spec must give a grid of one");
    let outcome = sweep(&mt, &MetaStrategy::Grid, seed);
    assert_eq!(outcome.leaderboard.len(), 1);
    let meta = &outcome.leaderboard[0];
    assert_eq!(meta.spec, spec, "ordinal 0 must expand to the base spec itself");

    // Reference: the same grid through the coordinate path.
    let entries = conv_entries();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        vec![(spec.label(), &spec as &dyn OptimizerFactory)];
    let jobs = grid_jobs(&entries, &factories, runs, seed);
    let curves = Scheduler::new(2).run(&jobs);
    let grouped = collate(factories.len() * entries.len(), &jobs, curves);
    let labels = vec![spec.label()];
    let aggs = grid_aggregates(&labels, entries.len(), grouped);
    let reference = &aggs[0].1;

    assert_eq!(meta.score, reference.score, "grid-of-one sweep must equal coordinate");
    assert_eq!(meta.per_space, reference.per_space_scores);
}

#[test]
fn sha_survivors_are_invariant_to_candidate_order() {
    let seed = 7u64;
    let forward = mt_with(ga_narrow(), 4, seed, 2);
    let shuffled = mt_with(ga_narrow(), 4, seed, 5);
    let rungs_fwd = successive_halving(&forward, vec![0, 1, 2, 3], 2);
    let rungs_rev = successive_halving(&shuffled, vec![2, 3, 1, 0, 1], 2);
    assert_eq!(rungs_fwd, rungs_rev, "rung trace must be a function of the candidate set");
    // Seeds-per-rung escalation: non-decreasing, ending at the full count.
    assert!(rungs_fwd.windows(2).all(|w| w[0].runs <= w[1].runs));
    assert_eq!(rungs_fwd.last().unwrap().runs, 4);
    assert_eq!(rungs_fwd.last().unwrap().survivors.len(), 1);
    // Survivors always come from the rung's own candidates.
    for r in &rungs_fwd {
        assert!(r.survivors.iter().all(|s| r.candidates.contains(s)));
    }
}

#[test]
fn all_meta_strategies_run_over_two_tuned_optimizers() {
    // grid + random over SA; sha + optimizer-as-meta over GA (the
    // acceptance matrix: 4 strategies x 2 tuned optimizers, interleaved).
    let sa = mt_with(sa_narrow(), 2, 3, 2);
    let grid = sweep(&sa, &MetaStrategy::Grid, 3);
    assert_eq!(grid.leaderboard.len(), 4, "t0 domain has 4 values");
    let sa2 = mt_with(sa_narrow(), 2, 3, 2);
    let random = sweep(&sa2, &MetaStrategy::Random { evals: 2 }, 3);
    assert_eq!(random.leaderboard.len(), 2);
    // Random's sample is a subset of the grid with identical memo scores.
    for r in &random.leaderboard {
        let full = grid.leaderboard.iter().find(|g| g.ordinal == r.ordinal).unwrap();
        assert_eq!(full.score, r.score);
    }

    let ga = mt_with(ga_narrow(), 2, 3, 2);
    let sha = sweep(&ga, &MetaStrategy::Sha { eta: 2, evals: 4 }, 3);
    assert!(!sha.rungs.is_empty());
    assert!(!sha.leaderboard.is_empty());

    // The repo's own SA tunes the repo's own GA through a TuningContext
    // over the meta backend.
    let ga2 = mt_with(ga_narrow(), 2, 3, 2);
    let strategy = MetaStrategy::parse("sa", 4).unwrap();
    let searched = sweep(&ga2, &strategy, 3);
    assert!(!searched.leaderboard.is_empty());
    assert!(searched.leaderboard.len() <= 4 + 1, "budget caps fresh meta-evals");
    assert!(searched.leaderboard.iter().all(|r| r.score.is_finite()));
    // Ranked best-first with deterministic tie-breaks.
    assert!(searched
        .leaderboard
        .windows(2)
        .all(|w| w[0].score > w[1].score
            || (w[0].score == w[1].score && w[0].ordinal < w[1].ordinal)));
}

#[test]
fn sweep_seed_changes_decorrelate_meta_configs_not_ordinal_zero() {
    // Ordinal-derived seeding: different ordinals get different inner base
    // seeds under the same sweep seed, and ordinal 0 always inherits the
    // sweep seed itself.
    assert_eq!(meta_seed(123, 0), 123);
    let seeds: Vec<u64> = (0..16).map(|o| meta_seed(123, o)).collect();
    let mut dedup = seeds.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), seeds.len(), "ordinal seeds must not collide");
}
