//! Integration: the pluggable evaluation-backend seam.
//!
//! - The cached backend must reproduce the *pre-redesign* evaluator
//!   bit-for-bit (golden reference reimplemented here from the old
//!   `TuningContext::evaluate`), submitted one-at-a-time or in batches.
//! - Every registry optimizer must stay deterministic per seed, through
//!   both its `run` path and (where supported) the generic ask/tell
//!   driver.
//! - Grid output must remain byte-identical across scheduler widths now
//!   that population optimizers batch whole generations.
//! - The measured backend must be lazy, memoized across jobs, and
//!   drivable through the same job graph (fake runner; the PJRT-backed
//!   smoke lives in integration_runtime.rs behind the `pjrt` feature).

use std::collections::HashMap;

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::cache::RUNS_PER_EVAL;
use llamea_kt::tuning::{Cache, TuningContext};

fn conv_cache() -> Cache {
    Cache::build(Application::Convolution, GpuSpec::by_name("A4000").unwrap())
}

/// The pre-redesign evaluator, verbatim: unique-ordinal-keyed observation
/// noise, full cost for fresh configs, epsilon for repeats, trajectory
/// stamped after the charge. Any drift between this and the new
/// backend-based context is a regression against pre-redesign results.
struct ReferenceEvaluator<'a> {
    cache: &'a Cache,
    clock_s: f64,
    unique_evals: u64,
    seen: HashMap<u32, Option<f64>>,
    best_ms: f64,
    trajectory: Vec<(f64, f64)>,
}

const CACHED_EVAL_COST_S: f64 = 0.05;

impl<'a> ReferenceEvaluator<'a> {
    fn new(cache: &'a Cache) -> Self {
        ReferenceEvaluator {
            cache,
            clock_s: 0.0,
            unique_evals: 0,
            seen: HashMap::new(),
            best_ms: f64::INFINITY,
            trajectory: Vec::new(),
        }
    }

    fn evaluate(&mut self, i: u32) -> Option<f64> {
        if let Some(&v) = self.seen.get(&i) {
            self.clock_s += CACHED_EVAL_COST_S;
            return v;
        }
        self.clock_s += self.cache.eval_cost_s(i);
        self.unique_evals += 1;
        let value = self.cache.true_mean_ms(i).map(|_| {
            let mut sum = 0.0;
            let base = self.unique_evals.wrapping_mul(RUNS_PER_EVAL as u64 + 1);
            for r in 0..RUNS_PER_EVAL as u64 {
                sum += self.cache.observe_ms(i, base + r).unwrap();
            }
            sum / RUNS_PER_EVAL as f64
        });
        self.seen.insert(i, value);
        if let Some(v) = value {
            if v < self.best_ms {
                self.best_ms = v;
                self.trajectory.push((self.clock_s, v));
            }
        }
        value
    }
}

/// A mixed evaluation sequence with repeats, spread over the space.
fn scripted_sequence(n: usize, len: u32) -> Vec<u32> {
    let mut rng = llamea_kt::util::rng::Rng::new(0xBEEF);
    (0..n)
        .map(|k| {
            if k % 5 == 4 {
                // Revisit an earlier config (dedup path).
                (k as u32 / 2) % len
            } else {
                rng.below(len as usize) as u32
            }
        })
        .collect()
}

#[test]
fn cached_backend_matches_pre_redesign_golden_sequentially() {
    let cache = conv_cache();
    let seq = scripted_sequence(400, cache.len() as u32);
    let mut reference = ReferenceEvaluator::new(&cache);
    let mut ctx = TuningContext::new(&cache, 1e12, 7);
    for &i in &seq {
        assert_eq!(reference.evaluate(i), ctx.evaluate(i), "config {}", i);
    }
    assert_eq!(reference.clock_s, ctx.elapsed_s());
    assert_eq!(reference.unique_evals, ctx.unique_evals());
    assert_eq!(reference.trajectory, ctx.trajectory);
}

#[test]
fn cached_backend_matches_pre_redesign_golden_in_batches() {
    let cache = conv_cache();
    let seq = scripted_sequence(400, cache.len() as u32);
    let mut reference = ReferenceEvaluator::new(&cache);
    let ref_vals: Vec<Option<f64>> = seq.iter().map(|&i| reference.evaluate(i)).collect();

    // Same sequence, chunked into uneven batches.
    let mut ctx = TuningContext::new(&cache, 1e12, 7);
    let mut got: Vec<Option<f64>> = Vec::new();
    for chunk in seq.chunks(23) {
        got.extend(ctx.evaluate_batch(chunk));
    }
    assert_eq!(ref_vals, got);
    assert_eq!(reference.clock_s, ctx.elapsed_s());
    assert_eq!(reference.trajectory, ctx.trajectory);
}

#[test]
fn every_registry_optimizer_is_seed_deterministic() {
    let cache = conv_cache();
    for name in llamea_kt::optimizers::all_names() {
        let run = |seed: u64| {
            let mut opt = llamea_kt::optimizers::by_name(name).unwrap();
            let mut ctx = TuningContext::new(&cache, 250.0, seed);
            opt.run(&mut ctx);
            (ctx.trajectory.clone(), ctx.unique_evals(), ctx.eval_calls())
        };
        assert_eq!(run(11), run(11), "{} diverged for equal seeds", name);
        assert_ne!(run(11).0, run(12).0, "{} ignored its seed", name);
    }
}

#[test]
fn ask_tell_driver_is_deterministic_where_supported() {
    let cache = conv_cache();
    let mut supported = 0;
    for name in llamea_kt::optimizers::all_names() {
        let run = |seed: u64| {
            let mut opt = llamea_kt::optimizers::by_name(name).unwrap();
            let mut ctx = TuningContext::new(&cache, 200.0, seed);
            let batched = llamea_kt::optimizers::run_ask_tell(opt.as_mut(), &mut ctx);
            (batched, ctx.trajectory.clone(), ctx.batched_evals())
        };
        let (batched, trajectory, batched_evals) = run(21);
        if !batched {
            continue;
        }
        supported += 1;
        assert!(!trajectory.is_empty(), "{} found nothing via ask/tell", name);
        assert!(batched_evals > 0, "{} never used the batch path", name);
        assert_eq!(run(21), (batched, trajectory, batched_evals), "{} nondeterministic", name);
    }
    // random, ga, de, pso at minimum.
    assert!(supported >= 4, "only {} optimizers support ask/tell", supported);
}

#[test]
fn grid_output_identical_across_widths_with_batching_optimizers() {
    use llamea_kt::coordinator::{grid_jobs, CacheKey, CacheRegistry, Scheduler};
    use llamea_kt::methodology::OptimizerFactory;
    use llamea_kt::optimizers::OptimizerSpec;
    let reg = CacheRegistry::new();
    let entries = vec![reg.entry(CacheKey::parse("convolution@A4000").unwrap())];
    // The batch-native and init-batching optimizers specifically.
    let owned: Vec<(String, OptimizerSpec)> = ["ga", "de", "pso"]
        .iter()
        .map(|n| (n.to_string(), OptimizerSpec::named(*n)))
        .collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        owned.iter().map(|(l, s)| (l.clone(), s as &dyn OptimizerFactory)).collect();
    let jobs = grid_jobs(&entries, &factories, 3, 4242);
    let narrow = Scheduler::new(1).run(&jobs);
    let wide = Scheduler::new(llamea_kt::util::parallel::test_width(8)).run(&jobs);
    assert_eq!(narrow, wide, "thread width changed batched-optimizer results");
}

// ---------------------------------------------------------- measured seam

mod measured {
    use llamea_kt::methodology::{run_many, NamedFactory, SpaceSetup};
    use llamea_kt::runtime::measured::NOMINAL_EVAL_COST_S;
    use llamea_kt::runtime::measured_testing::{gemm_grid, FakeRunner};
    use llamea_kt::runtime::MeasuredSource;
    use llamea_kt::tuning::BackendSource;

    #[test]
    fn measured_source_drives_the_job_graph_and_measures_once() {
        // 3x2 grid, fully covered: 6 variants.
        let set = gemm_grid(&[32, 64, 128], &[32, 64]);
        let runner = FakeRunner::default();
        let source = MeasuredSource::new(&runner, &set, "gemm", 1, 3, 5).unwrap();
        let setup = SpaceSetup::uncalibrated(120.0, NOMINAL_EVAL_COST_S);
        // Many seeds, two optimizer families, one shared measurement store.
        let curves = run_many(&source, &setup, &NamedFactory("random".into()), 4, 99);
        assert_eq!(curves.len(), 4);
        assert!(curves.iter().all(|c| c.len() == setup.times.len()));
        let after_random = runner.calls();
        assert!(after_random <= 6, "at most one compile per variant, got {}", after_random);
        assert!(after_random > 0);
        // A second grid over the same source re-measures nothing.
        run_many(&source, &setup, &NamedFactory("ga".into()), 3, 7);
        assert_eq!(
            runner.calls(),
            after_random,
            "second optimizer grid must reuse the measurement store"
        );
        assert_eq!(source.space_id(), "gemm-measured");
        assert!(source.errors().is_empty());
        assert!(!source.results().is_empty());
    }
}
