//! Integration: the observability layer's master contract.
//!
//! - **Out-of-band invariant**: report bytes are identical with tracing
//!   on vs off, for a coordinate-style grid and a grid sweep, at widths
//!   1 and 8 (`LLAMEA_KT_TEST_THREADS` governs the wide width, matching
//!   the CI matrix).
//! - **Trace well-formedness**: every exported event is a complete
//!   ("X") span — closed by construction — the canonical
//!   `(epoch-ns, thread, seq)` order is monotone, and the trace carries
//!   spans from at least four layers of the stack.
//! - **Disabled recorder**: a full grid run with recording off stores
//!   exactly zero events and an empty metrics snapshot.

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use llamea_kt::coordinator::{
    coordinate_report, grid_jobs, CacheKey, CacheRegistry, Executor, SpaceEntry, COORDINATE_TITLE,
};
use llamea_kt::hypertune::{sweep, sweep_json, MetaStrategy, MetaTuning};
use llamea_kt::methodology::OptimizerFactory;
use llamea_kt::obs;
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::util::json::Json;
use llamea_kt::util::parallel::test_width;

/// Recording is process-global; every test here toggles it, so they
/// serialize on one lock and restore the disabled state before exiting.
fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

fn conv_entries(reg: &CacheRegistry) -> Vec<Arc<SpaceEntry>> {
    vec![
        reg.entry(CacheKey::parse("convolution@A4000").unwrap()),
        reg.entry(CacheKey::parse("convolution@W6600").unwrap()),
    ]
}

/// GA with everything but `elites` pinned: a 4-point meta space keeps
/// the sweep cheap.
fn ga_narrow() -> OptimizerSpec {
    OptimizerSpec::parse(
        "ga:population_size=8,tournament_k=2,crossover_rate=0.8,mutation_rate_factor=0.8",
    )
    .unwrap()
}

/// One coordinate-style grid run at `width`, serialized to report bytes.
/// Library-level reports carry no `"caches"` block (that is `main`'s
/// run-metadata append), so this is a true byte-for-byte comparison.
fn coordinate_bytes(reg: &CacheRegistry, width: usize) -> String {
    let entries = conv_entries(reg);
    let owned: Vec<(String, OptimizerSpec)> = ["sa", "random"]
        .iter()
        .map(|n| (n.to_string(), OptimizerSpec::named(*n)))
        .collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        owned.iter().map(|(l, s)| (l.clone(), s as &dyn OptimizerFactory)).collect();
    let jobs = grid_jobs(&entries, &factories, 2, 2026);
    let batch = Executor::new(width).run_jobs(&jobs);
    let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch).to_string()
}

/// One grid-strategy sweep at `width`, serialized to report bytes.
fn sweep_bytes(width: usize) -> String {
    let entries =
        vec![CacheRegistry::global().entry(CacheKey::parse("convolution@A4000").unwrap())];
    let mt = MetaTuning::new(ga_narrow(), entries, 2, 9, Some(width)).unwrap();
    let outcome = sweep(&mt, &MetaStrategy::Grid, 9);
    sweep_json(&mt, &outcome, 9).to_string()
}

/// The master contract: observability is strictly out-of-band, so the
/// exact report bytes of a traced run equal the untraced reference at
/// every thread width.
#[test]
fn reports_are_byte_identical_with_tracing_on_and_off() {
    let _g = guard();
    obs::enable(false, false);
    let reg = CacheRegistry::global();
    let coordinate_ref = coordinate_bytes(reg, 1);
    let sweep_ref = sweep_bytes(1);
    obs::enable(true, true);
    for width in [1, test_width(8)] {
        assert_eq!(
            coordinate_bytes(reg, width),
            coordinate_ref,
            "coordinate report changed with tracing on at width {}",
            width
        );
        assert_eq!(
            sweep_bytes(width),
            sweep_ref,
            "sweep report changed with tracing on at width {}",
            width
        );
    }
    obs::enable(false, false);
    obs::reset();
}

#[test]
fn trace_is_well_formed_and_spans_every_layer() {
    let _g = guard();
    obs::enable(true, true);
    obs::reset();
    // A fresh registry so the cache-resolution spans fire here (the
    // global registry may already hold these keys); the sweep adds the
    // hypertune layer on top of executor + tuning.
    let reg = CacheRegistry::new();
    let _ = coordinate_bytes(&reg, test_width(8));
    let _ = sweep_bytes(2);
    let doc = obs::export::chrome_trace();
    obs::enable(false, false);
    obs::reset();

    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "a full grid + sweep must record spans");
    let mut last = (0u64, 0u64, 0u64);
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"), "event {} not complete", i);
        assert!(e.get("dur").and_then(Json::as_usize).is_some(), "event {} has no dur", i);
        let args = e.get("args").expect("events carry args");
        let key = (
            args.get("ns").and_then(Json::as_usize).expect("exact ns in args") as u64,
            e.get("tid").and_then(Json::as_usize).expect("tid") as u64,
            args.get("seq").and_then(Json::as_usize).expect("seq in args") as u64,
        );
        assert!(i == 0 || last <= key, "canonical order violated: {:?} then {:?}", last, key);
        last = key;
    }
    // Spans from at least four layers of the stack.
    for prefix in ["registry.", "executor.", "tuning.", "hypertune."] {
        assert!(
            events.iter().any(|e| {
                e.get("name").and_then(Json::as_str).unwrap_or("").starts_with(prefix)
            }),
            "no {}* span in the trace",
            prefix
        );
    }
}

#[test]
fn disabled_recorder_stores_exactly_zero_events_under_a_full_grid() {
    let _g = guard();
    obs::enable(false, false);
    obs::reset();
    let reg = CacheRegistry::new();
    let _ = coordinate_bytes(&reg, test_width(8));
    assert_eq!(obs::event_count(), 0, "disabled recorder must store nothing");
    assert_eq!(obs::export::metrics_text(), "", "disabled metrics must be empty");
}
