//! End-to-end tests of the remote fleet over real TCP connections — the
//! master contract extended across hosts: a fleet-collated coordinate
//! report is **byte-identical** to the single-process run of the same
//! grid, for any fleet size, any worker pool width, and any worker
//! loss/retry timing. Fault injection uses scripted fake workers (a
//! listener that dies after `hello`, one that delivers every row twice)
//! alongside real in-process [`Worker`] daemons on port 0.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use llamea_kt::coordinator::{
    coordinate_report, grid_jobs, BatchRunner, CacheKey, CacheRegistry, Executor, JobsSummary,
    OwnedJob, SpaceEntry, COORDINATE_TITLE,
};
use llamea_kt::methodology::OptimizerFactory;
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::remote::protocol::{done_event, hello_event, row_event, MAX_LINE_BYTES};
use llamea_kt::remote::{RemoteRunner, Worker, WorkerConfig, WorkerHandle, WorkerTally};
use llamea_kt::util::json::Json;

struct Fleet {
    addr: String,
    handle: WorkerHandle,
    join: std::thread::JoinHandle<std::io::Result<()>>,
}

fn start_worker(threads: usize) -> Fleet {
    let worker = Worker::bind(
        "127.0.0.1:0",
        WorkerConfig { threads: Some(threads), ..Default::default() },
    )
    .expect("bind ephemeral port");
    let addr = worker.local_addr().to_string();
    let handle = worker.handle();
    let join = std::thread::spawn(move || worker.run());
    Fleet { addr, handle, join }
}

impl Fleet {
    fn stop(self) {
        self.handle.shutdown();
        self.join.join().unwrap().expect("accept loop exits cleanly");
    }
}

/// The single-process report for a coordinate grid: the exact assembly
/// path `llamea-kt coordinate --out` uses, without the `"caches"` block
/// `write_report` appends.
fn direct_report(spaces: &[&str], opts: &[&str], runs: usize, seed: u64, width: usize) -> String {
    let registry = CacheRegistry::global();
    let entries: Vec<Arc<SpaceEntry>> =
        spaces.iter().map(|s| registry.entry(CacheKey::parse(s).unwrap())).collect();
    let specs: Vec<OptimizerSpec> =
        opts.iter().map(|o| OptimizerSpec::parse(o).unwrap()).collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let jobs = grid_jobs(&entries, &factories, runs, seed);
    let batch = Executor::with_threads(Some(width)).fail_fast().run_jobs(&jobs);
    let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
    let labels: Vec<String> = factories.iter().map(|(l, _)| l.clone()).collect();
    coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch).to_string()
}

fn owned_grid(spaces: &[&str], opts: &[&str], runs: usize, seed: u64) -> (Vec<OwnedJob>, Vec<String>, Vec<String>) {
    let registry = CacheRegistry::global();
    let entries: Vec<Arc<SpaceEntry>> =
        spaces.iter().map(|s| registry.entry(CacheKey::parse(s).unwrap())).collect();
    let specs: Vec<Arc<OptimizerSpec>> =
        opts.iter().map(|o| Arc::new(OptimizerSpec::parse(o).unwrap())).collect();
    let jobs = OwnedJob::grid(&entries, &specs, runs, seed);
    let ids: Vec<String> = entries.iter().map(|e| e.cache.id()).collect();
    let labels: Vec<String> = specs.iter().map(|s| s.label()).collect();
    (jobs, ids, labels)
}

/// Run the grid through a fleet and render the collated report.
fn fleet_report(
    workers: Vec<String>,
    spaces: &[&str],
    opts: &[&str],
    runs: usize,
    seed: u64,
) -> (String, Vec<WorkerTally>) {
    let (jobs, ids, labels) = owned_grid(spaces, opts, runs, seed);
    let runner = RemoteRunner::new(workers);
    let batch = runner.run_batch(&jobs, &|_| {});
    (coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch).to_string(), runner.tallies())
}

#[test]
fn fleet_report_is_byte_identical_to_direct_at_widths_1_and_8() {
    let spaces = ["convolution@A4000"];
    let opts = ["sa", "random"];
    let reference = direct_report(&spaces, &opts, 3, 7, 2);
    for width in [1usize, 8] {
        let a = start_worker(width);
        let b = start_worker(width);
        let (report, tallies) =
            fleet_report(vec![a.addr.clone(), b.addr.clone()], &spaces, &opts, 3, 7);
        assert_eq!(
            report, reference,
            "fleet bytes must not depend on worker pool width {}",
            width
        );
        assert!(
            tallies.iter().all(|t| !t.lost) && tallies.iter().map(|t| t.rows).sum::<usize>() == 6,
            "healthy fleet: every row fresh, no losses: {:?}",
            tallies
        );
        a.stop();
        b.stop();
    }
}

/// A scripted worker that accepts one batch, says hello, and dies
/// without delivering a single row — the "SIGKILL mid-grid" case.
fn dying_worker() -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let join = std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            let _ = reader.read_line(&mut line); // the run request
            let _ = (&stream)
                .write_all(format!("{}\n", hello_event(1, 1).to_string()).as_bytes());
            // Connection drops here: no rows, no done.
        }
    });
    (addr, join)
}

#[test]
fn a_worker_lost_mid_grid_redispatches_to_the_survivor_byte_identically() {
    let spaces = ["convolution@A4000"];
    let opts = ["sa", "random"];
    let reference = direct_report(&spaces, &opts, 3, 7, 2);
    let survivor = start_worker(2);
    let (dead_addr, dead_join) = dying_worker();
    let (report, tallies) =
        fleet_report(vec![dead_addr, survivor.addr.clone()], &spaces, &opts, 3, 7);
    assert_eq!(
        report, reference,
        "losing a worker mid-grid must not change a byte of the collated report"
    );
    assert!(tallies[0].lost, "the dead worker is recorded as lost: {:?}", tallies);
    assert!(!tallies[1].lost, "the survivor is not: {:?}", tallies);
    assert_eq!(
        tallies[1].rows, 6,
        "every row ultimately came from the survivor: {:?}",
        tallies
    );
    dead_join.join().unwrap();
    survivor.stop();
}

/// A scripted worker that delivers every row twice before `done` — the
/// "retry raced the original" case, compressed into one connection.
fn duplicating_worker(rows: Vec<(usize, usize, Vec<f64>)>) -> (String, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let join = std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            let _ = reader.read_line(&mut line);
            let send = |j: &Json| {
                let _ = (&stream).write_all(format!("{}\n", j.to_string()).as_bytes());
            };
            send(&hello_event(1, rows.len()));
            for (i, g, curve) in &rows {
                send(&row_event(*i, *g, curve));
                send(&row_event(*i, *g, curve));
            }
            let summary =
                JobsSummary { completed: rows.len(), cancelled: 0, failed: 0, cost_us: 0 };
            send(&done_event(&summary, 0, Json::Arr(Vec::new())));
        }
    });
    (addr, join)
}

#[test]
fn duplicate_rows_are_deduped_by_index() {
    let spaces = ["convolution@A4000"];
    let opts = ["sa"];
    let reference = direct_report(&spaces, &opts, 2, 9, 2);
    // Script the fake from the real curves so its duplicates are honest
    // re-deliveries, exactly what a retry raced by the original sends.
    let (jobs, ids, labels) = owned_grid(&spaces, &opts, 2, 9);
    let registry = CacheRegistry::global();
    let entries: Vec<Arc<SpaceEntry>> = spaces
        .iter()
        .map(|s| registry.entry(CacheKey::parse(s).unwrap()))
        .collect();
    let specs: Vec<OptimizerSpec> =
        opts.iter().map(|o| OptimizerSpec::parse(o).unwrap()).collect();
    let factories: Vec<(String, &dyn OptimizerFactory)> =
        specs.iter().map(|s| (s.label(), s as &dyn OptimizerFactory)).collect();
    let direct = Executor::with_threads(Some(2))
        .fail_fast()
        .run_jobs(&grid_jobs(&entries, &factories, 2, 9));
    let rows: Vec<(usize, usize, Vec<f64>)> = direct
        .handles
        .iter()
        .map(|h| (h.slot, h.group, h.outcome.curve().expect("completed").to_vec()))
        .collect();
    let n = rows.len();

    let (addr, join) = duplicating_worker(rows);
    let runner = RemoteRunner::new(vec![addr]);
    let batch = runner.run_batch(&jobs, &|_| {});
    let report = coordinate_report(COORDINATE_TITLE, &ids, &labels, &batch).to_string();
    assert_eq!(report, reference, "deduped fleet bytes must match the single-process run");
    let tallies = runner.tallies();
    assert_eq!(tallies[0].rows, n, "first delivery of each slot is fresh: {:?}", tallies);
    assert_eq!(
        tallies[0].duplicates, n,
        "second delivery of each slot is dropped as a duplicate: {:?}",
        tallies
    );
    assert!(!tallies[0].lost, "duplicates are benign, not a protocol violation: {:?}", tallies);
    join.join().unwrap();
}

#[test]
fn malformed_truncated_and_oversized_lines_get_structured_errors_not_hangs() {
    let worker = start_worker(1);

    // Malformed JSON, unknown commands, and non-UTF-8 all answer with an
    // error event and keep the connection serving.
    let stream = TcpStream::connect(&worker.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    for bad in ["{not json\n", "[]\n", "{\"cmd\":\"warp\"}\n", "{\"cmd\":\"run\",\"jobs\":[]}\n"] {
        (&stream).write_all(bad.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains(r#""event":"error""#), "{:?} -> {}", bad, line);
    }
    (&stream).write_all(b"\xff\xfe\xfd\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("not UTF-8"), "{}", line);
    drop(reader);
    drop(stream);

    // A resolvable-looking batch naming an unknown space aborts whole,
    // with a structured error — never a silently partial run.
    let stream = TcpStream::connect(&worker.addr).unwrap();
    (&stream)
        .write_all(
            b"{\"cmd\":\"run\",\"jobs\":[{\"index\":0,\"space\":\"nope@nowhere\",\
              \"opt\":\"sa\",\"seed\":\"1\",\"group\":0}]}\n",
        )
        .unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("unknown space 'nope@nowhere'"), "{}", line);
    drop(stream);

    // Same for an optimizer spec the local registry cannot reconstruct.
    let stream = TcpStream::connect(&worker.addr).unwrap();
    (&stream)
        .write_all(
            b"{\"cmd\":\"run\",\"jobs\":[{\"index\":0,\"space\":\"convolution@A4000\",\
              \"opt\":\"warp\",\"seed\":\"1\",\"group\":0}]}\n",
        )
        .unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("unknown optimizer spec 'warp'"), "{}", line);
    drop(stream);

    // A truncated final line (no newline before EOF) is still answered.
    let stream = TcpStream::connect(&worker.addr).unwrap();
    (&stream).write_all(b"{not json").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut response = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_to_string(&mut response).unwrap();
    assert!(response.contains(r#""event":"error""#), "{}", response);

    // An unterminated line past the 1 MiB cap is answered with an error,
    // never buffered unboundedly.
    let stream = TcpStream::connect(&worker.addr).unwrap();
    let oversized = vec![b'a'; MAX_LINE_BYTES + 1];
    (&stream).write_all(&oversized).unwrap();
    let mut response = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_to_string(&mut response).unwrap();
    assert!(response.contains("exceeds 1 MiB"), "{}", response);

    worker.stop();
}
