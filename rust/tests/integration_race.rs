//! Integration: portfolio racing over the executor seam — width
//! determinism of the race report, finalist bit-identity to standalone
//! runs, mid-race interruption through the external token, replay of the
//! recorded bandit-decision trajectory, and the acceptance property that
//! a raced portfolio is never worse than its best single arm's drain-all
//! run at the same canonical budget.
//!
//! Width-sensitive checks use `util::parallel::test_width` (the
//! `LLAMEA_KT_TEST_THREADS` knob) so CI's width matrix exercises them at
//! 1 and 8 workers.

use std::sync::atomic::{AtomicUsize, Ordering};

use llamea_kt::coordinator::{
    decide, job_seed, race_json, run_race, run_race_observed, Bandit, CacheKey, CacheRegistry,
    Progress, RaceConfig, TuningJob,
};
use llamea_kt::optimizers::OptimizerSpec;
use llamea_kt::util::cancel::CancelToken;
use llamea_kt::util::parallel::test_width;
use llamea_kt::util::stats;

fn specs(names: &[&str]) -> Vec<OptimizerSpec> {
    names.iter().map(|n| OptimizerSpec::named(*n)).collect()
}

fn cfg(rungs: usize, seed: u64, threads: usize) -> RaceConfig {
    RaceConfig { eta: 2, rungs, seed, threads: Some(threads), cancel: None }
}

/// The tentpole's determinism contract: the race report — decisions,
/// rewards, counters, curves, winner — is byte-identical for any worker
/// count, because the bandit consumes only modeled signals and results
/// land in stream slots.
#[test]
fn race_report_identical_across_thread_counts() {
    let reg = CacheRegistry::global();
    let entry = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
    let portfolio = specs(&["sa", "random", "greedy_ils", "bayes_opt"]);
    let narrow = run_race(&entry, &portfolio, &cfg(3, 17, 1));
    let wide = run_race(&entry, &portfolio, &cfg(3, 17, test_width(8)));
    assert_eq!(
        race_json(&narrow).to_string(),
        race_json(&wide).to_string(),
        "race report depends on executor width"
    );
    assert!(narrow.winner.is_some(), "a full race must crown a winner");
    assert!(narrow.cancellations > 0, "losers must be cancelled through the seam");
}

/// Finalist curves are bit-identical to the arm's standalone run — even
/// though doomed arms were being cancelled in the same rung batches. The
/// final rung reuses the canonical setup verbatim and arm seeds come
/// from `job_seed` with run index 0, so a finalist's curve must equal
/// the curve of a plain `coordinate --runs 1` job byte for byte.
#[test]
fn finalist_curves_match_standalone_runs_bit_for_bit() {
    let reg = CacheRegistry::global();
    let entry = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
    let portfolio = specs(&["sa", "random", "greedy_ils", "ga"]);
    let outcome = run_race(&entry, &portfolio, &cfg(2, 5, test_width(8)));
    assert!(outcome.cancellations > 0, "eta 2 over 4 arms must cancel someone");
    let space_id = entry.cache.space_id();
    let mut finalists = 0;
    for (arm, spec) in outcome.arms.iter().zip(&portfolio) {
        let Some(curve) = &arm.curve else { continue };
        finalists += 1;
        let solo = TuningJob {
            source: &entry.cache,
            setup: &entry.setup,
            factory: spec,
            seed: job_seed(5, &space_id, &spec.label(), 0),
            group: 0,
        }
        .execute();
        assert_eq!(curve, &solo, "{}: raced curve diverged from the standalone run", arm.label);
        assert_eq!(arm.score, Some(stats::mean(&solo)));
    }
    assert!(finalists >= 1, "the final rung must complete at least one arm");
}

/// External interruption (the CLI's SIGINT token) observed at a rung
/// boundary: the completed rung's scores survive, nothing is truncated,
/// and the outcome is flagged — no winner is invented from partial data.
#[test]
fn mid_race_interruption_keeps_completed_rungs() {
    let reg = CacheRegistry::global();
    let entry = reg.entry(CacheKey::parse("convolution@A4000").unwrap());
    let portfolio = specs(&["sa", "random", "greedy_ils"]);
    let token = CancelToken::new();
    let mut config = cfg(3, 11, test_width(8));
    config.cancel = Some(token.clone());
    // Fire the external token once every rung-0 job has finished: the
    // race must notice at the rung boundary and stop before deciding.
    let finished = AtomicUsize::new(0);
    let outcome = run_race_observed(&entry, &portfolio, &config, &|ev| {
        if matches!(ev, Progress::Finished { .. })
            && finished.fetch_add(1, Ordering::SeqCst) + 1 == 3
        {
            token.cancel();
        }
    });
    assert!(outcome.interrupted, "a fired external token must flag the outcome");
    assert!(outcome.winner.is_none(), "an interrupted race crowns no winner");
    assert!(outcome.decisions.is_empty(), "interruption lands before the decision");
    assert_eq!(outcome.jobs.completed, 3, "the completed rung is preserved");
    for arm in &outcome.arms {
        assert_eq!(arm.scores.len(), 1, "{}: rung-0 score must survive", arm.label);
        assert!(arm.scores[0].is_finite());
        assert!(arm.evals > 0, "{}: probe stats must be captured", arm.label);
    }
}

/// Decisions are replayable: feeding the recorded per-rung rewards to a
/// fresh bandit through the same pure `decide` rule reproduces every
/// survivor/eliminated split exactly. This is what makes the `"race"`
/// report block an audit trail rather than a summary.
#[test]
fn recorded_decision_trajectory_replays_exactly() {
    let reg = CacheRegistry::global();
    let entry = reg.entry(CacheKey::parse("convolution@W6600").unwrap());
    let portfolio = specs(&["sa", "random", "greedy_ils", "ga", "pso", "bayes_opt"]);
    let outcome = run_race(&entry, &portfolio, &cfg(3, 23, test_width(8)));
    assert!(outcome.decisions.len() >= 2, "6 arms over 3 rungs decide at least twice");
    let n = portfolio.len();
    let mut bandit = Bandit::new(n);
    let mut live: Vec<usize> = (0..n).collect();
    for (i, d) in outcome.decisions.iter().enumerate() {
        // A live arm's score at decision `i` is its rung-`i` entry (it
        // played every rung so far); eliminated arms are never ranked.
        let last: Vec<f64> = (0..n)
            .map(|a| outcome.arms[a].scores.get(i).copied().unwrap_or(f64::NEG_INFINITY))
            .collect();
        let (survivors, eliminated) = decide(&mut bandit, &live, &d.rewards, &last, 2);
        assert_eq!(survivors, d.survivors, "decision {} survivors diverged on replay", i);
        assert_eq!(eliminated, d.eliminated, "decision {} eliminations diverged on replay", i);
        live = survivors;
    }
}

/// The acceptance property: on both seed spaces, an 8-arm raced
/// portfolio reaches a best-found score at least as good as the best
/// single arm's drain-all run at the same canonical budget. The solo
/// goldens are computed in-test from the same jobs the `coordinate` grid
/// would run — nothing stored.
#[test]
fn raced_portfolio_matches_best_solo_arm_on_seed_spaces() {
    let reg = CacheRegistry::global();
    let portfolio =
        specs(&["hybrid_vndx", "sa", "greedy_ils", "ga", "pso", "mls", "random", "bayes_opt"]);
    for key in ["convolution@A4000", "convolution@W6600"] {
        let entry = reg.entry(CacheKey::parse(key).unwrap());
        let space_id = entry.cache.space_id();
        let outcome = run_race(&entry, &portfolio, &cfg(2, 2026, test_width(8)));
        let raced = outcome.best_score().expect("a full race must score a winner");
        let mut best_solo = f64::NEG_INFINITY;
        let mut best_label = String::new();
        for spec in &portfolio {
            let curve = TuningJob {
                source: &entry.cache,
                setup: &entry.setup,
                factory: spec,
                seed: job_seed(2026, &space_id, &spec.label(), 0),
                group: 0,
            }
            .execute();
            let score = stats::mean(&curve);
            if score > best_solo {
                best_solo = score;
                best_label = spec.label();
            }
        }
        assert!(
            raced >= best_solo,
            "{}: raced portfolio scored {} but solo {} reached {}",
            key,
            raced,
            best_label,
            best_solo
        );
    }
}
