//! Integration: the full LLaMEA loop produces optimizers that work on
//! held-out spaces, and the with-info condition helps on average.

use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::llamea::{evolve, EvolutionConfig, GenomeOptimizer, MockLlm, SpaceInfo};
use llamea_kt::methodology::{run_many, FnFactory, SpaceSetup};
use llamea_kt::searchspace::Application;
use llamea_kt::tuning::Cache;
use llamea_kt::util::stats;

#[test]
fn evolved_optimizer_transfers_to_unseen_gpu() {
    let app = Application::Convolution;
    let space = std::sync::Arc::new(app.build_space());
    let train: Vec<Cache> = ["A100", "A4000"]
        .iter()
        .map(|g| Cache::build_with_space(app, GpuSpec::by_name(g).unwrap(), space.clone()))
        .collect();
    let setups: Vec<SpaceSetup> = train.iter().map(SpaceSetup::new).collect();
    let info = SpaceInfo::from_cache(&train[0], &setups[0]);
    let mut config = EvolutionConfig::paper_defaults(app.name(), Some(info));
    config.llm_call_budget = 24;
    config.eval_runs = 3;
    let result = evolve(&config, &mut MockLlm::new(3), &train, 3);
    assert!(result.best.fitness > 0.0, "train fitness {}", result.best.fitness);

    // Held-out: unseen AMD GPU.
    let test = Cache::build_with_space(app, GpuSpec::by_name("W7800").unwrap(), space);
    let setup = SpaceSetup::new(&test);
    let genome = result.best.genome.clone();
    let factory = FnFactory {
        f: move || Box::new(GenomeOptimizer::new(genome.clone()))
            as Box<dyn llamea_kt::optimizers::Optimizer>,
        name: "evolved".into(),
    };
    let curves = run_many(&test, &setup, &factory, 20, 17);
    let score = stats::mean(&stats::mean_curve(&curves));
    assert!(score > 0.0, "held-out score {:+.3}", score);
}

#[test]
fn token_accounting_is_complete() {
    let app = Application::Dedispersion;
    let caches = vec![Cache::build(app, GpuSpec::by_name("A4000").unwrap())];
    let mut config = EvolutionConfig::paper_defaults(app.name(), None);
    config.llm_call_budget = 15;
    config.eval_runs = 2;
    let result = evolve(&config, &mut MockLlm::new(9), &caches, 1);
    assert_eq!(result.llm_calls, 15);
    // Every call contributes prompt tokens; totals must dominate call count.
    assert!(result.tokens.prompt_tokens >= 15 * 50);
    assert!(result.tokens.completion_tokens > 0);
}
