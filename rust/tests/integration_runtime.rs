//! Integration tests for the PJRT measured path. These need the artifacts
//! directory produced by `make artifacts`; they are skipped (with a note)
//! when it is absent so `cargo test` works on a fresh checkout.

use std::path::Path;

use llamea_kt::runtime::{
    gemm_reference, measure_kernel, variant_space, ArtifactSet, PjrtRuntime,
};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

#[test]
fn gemm_variant_executes_and_matches_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let runtime = PjrtRuntime::new().unwrap();
    let artifact = set
        .for_kernel("gemm")
        .into_iter()
        .find(|a| a.params["block_m"] == 64 && a.params["block_n"] == 64)
        .expect("gemm 64x64 variant");
    let (variant, inputs) = runtime.prepare(artifact, 42).unwrap();
    let out = variant.run_f32(&inputs).unwrap();

    // Reference: alpha=1.5, beta=0.5 baked in python/compile/model.py.
    let a = inputs[0].to_vec::<f32>().unwrap();
    let b = inputs[1].to_vec::<f32>().unwrap();
    let c = inputs[2].to_vec::<f32>().unwrap();
    let want = gemm_reference(&a, &b, &c, 256, 256, 256, 1.5, 0.5);
    assert_eq!(out.len(), want.len());
    let max_err = out
        .iter()
        .zip(&want)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0f64, f64::max);
    assert!(max_err < 1e-2, "max err {}", max_err);
}

#[test]
fn all_gemm_variants_agree_with_each_other() {
    // The auto-tuning premise: every configuration is functionally
    // equivalent. Verify a sample of variants produce identical results.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let runtime = PjrtRuntime::new().unwrap();
    let gemms = set.for_kernel("gemm");
    let mut reference: Option<Vec<f32>> = None;
    for artifact in gemms.iter().step_by(7) {
        let (variant, inputs) = runtime.prepare(artifact, 9).unwrap();
        let out = variant.run_f32(&inputs).unwrap();
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                let max_err = out
                    .iter()
                    .zip(r)
                    .map(|(x, y)| (x - y).abs() as f64)
                    .fold(0.0f64, f64::max);
                assert!(max_err < 1e-2, "{}: max err {}", artifact.name, max_err);
            }
        }
    }
    assert!(reference.is_some());
}

#[test]
fn timing_is_positive_and_stable() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let runtime = PjrtRuntime::new().unwrap();
    let artifact = set.for_kernel("gemm")[0];
    let (variant, inputs) = runtime.prepare(artifact, 1).unwrap();
    let t = variant.time(&inputs, 1, 5).unwrap();
    assert!(t.mean_ms > 0.0);
    assert!(t.min_ms <= t.mean_ms);
    assert_eq!(t.reps, 5);
    assert!(variant.compile_s > 0.0);
}

#[test]
fn measured_cache_over_dedispersion_variants() {
    // Dedispersion has the smallest variant grid -> fastest full measure.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let runtime = PjrtRuntime::new().unwrap();
    let measured = measure_kernel(&runtime, &set, "dedispersion", 1, 3, 7).unwrap();
    assert_eq!(measured.measurements.len(), set.for_kernel("dedispersion").len());
    let cache = &measured.cache;
    assert!(cache.optimum_ms > 0.0);
    assert!(cache.median_ms >= cache.optimum_ms);
    // The methodology runs end-to-end on the measured cache.
    let setup = llamea_kt::methodology::SpaceSetup::new(cache);
    assert!(setup.budget_s > 0.0);
}

/// MeasuredBackend smoke over the real PJRT runtime: lazy, memoized,
/// optimizer-driven measurement. Gated behind the `pjrt` feature (plus
/// the artifacts directory) — stub builds have no executing runtime.
#[cfg(feature = "pjrt")]
#[test]
fn measured_backend_lazy_tuning_smoke() {
    use llamea_kt::runtime::MeasuredSource;
    use llamea_kt::tuning::{BackendSource, TuningContext};
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    let runtime = PjrtRuntime::new().unwrap();
    let source = MeasuredSource::new(&runtime, &set, "dedispersion", 1, 3, 11).unwrap();
    let mut backend = source.backend();
    let mut ctx = TuningContext::with_backend(backend.as_mut(), 30.0, 5);
    let mut opt = llamea_kt::optimizers::by_name("random").unwrap();
    opt.run(&mut ctx);
    assert!(ctx.best().is_some(), "lazy tuning found no runnable variant");
    assert!(source.measured_count() > 0);
    assert!(source.measured_count() as u64 >= ctx.unique_evals() / 2);
}

#[test]
fn variant_space_covers_all_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let set = ArtifactSet::load(&dir).unwrap();
    for kernel in set.kernels() {
        let space = variant_space(&kernel, &set).unwrap();
        for a in set.for_kernel(&kernel) {
            let cfg = llamea_kt::runtime::measured::config_of(a, &space);
            assert!(space.index_of(&cfg).is_some(), "{}", a.name);
        }
    }
}
