//! Table 3 bench: target vs non-target regrouping (reuses the Table 2
//! pipeline; the regrouping itself is measured separately).
mod common;
use llamea_kt::harness::{evaluate_generated, generate_all, ExpOptions};

fn main() {
    common::section("Table 3: target vs non-target (trimmed)");
    let opts = ExpOptions { runs: 8, gen_runs: 1, llm_calls: 16, seed: 7, ..ExpOptions::default() };
    let generated = generate_all(&opts, false);
    let t0 = std::time::Instant::now();
    let (_, _, t3) = evaluate_generated(&generated, &opts, std::path::Path::new("results"));
    println!("evaluation + regrouping took {:?}", t0.elapsed());
    println!("{}", t3.to_text());
}
