//! Tiny bench harness (criterion unavailable offline): timed sections with
//! warmup + repetitions, reporting mean ± std — and, for perf-trajectory
//! tracking across PRs, machine-readable records that [`write_json`] dumps
//! as `{name, iters, ns_per_iter}` rows (CI uploads `BENCH_hotpath.json`
//! as an artifact).
use std::time::Instant;

use llamea_kt::util::json::Json;

/// One timed section's result: `iters` timed repetitions averaging
/// `ns_per_iter` nanoseconds each (± `ns_std`).
#[allow(dead_code)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub ns_per_iter: f64,
    pub ns_std: f64,
}

#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup { f(); }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / reps.max(1) as f64;
    println!("bench {:40} {:>12.3} ms ± {:>8.3} ms  ({} reps)",
        name, mean * 1e3, var.sqrt() * 1e3, reps);
    BenchResult {
        name: name.to_string(),
        iters: reps,
        ns_per_iter: mean * 1e9,
        ns_std: var.sqrt() * 1e9,
    }
}

#[allow(dead_code)]
pub fn section(name: &str) {
    println!("\n== {} ==", name);
}

/// Write bench records as a JSON array of `{name, iters, ns_per_iter}`
/// objects (plus the std), so future PRs can diff the perf trajectory.
#[allow(dead_code)]
pub fn write_json(path: &std::path::Path, results: &[BenchResult]) {
    let mut arr = Json::Arr(Vec::new());
    for r in results {
        let mut o = Json::obj();
        o.set("name", r.name.as_str())
            .set("iters", r.iters)
            .set("ns_per_iter", r.ns_per_iter)
            .set("ns_std", r.ns_std);
        arr.push(o);
    }
    llamea_kt::util::json::write_file(path, &arr).expect("write bench json");
    println!("\nwrote {}", path.display());
}
