//! Tiny bench harness (criterion unavailable offline): timed sections with
//! warmup + repetitions, reporting mean ± std.
use std::time::Instant;

#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) {
    for _ in 0..warmup { f(); }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / reps.max(1) as f64;
    println!("bench {:40} {:>12.3} ms ± {:>8.3} ms  ({} reps)",
        name, mean * 1e3, var.sqrt() * 1e3, reps);
}

#[allow(dead_code)]
pub fn section(name: &str) {
    println!("\n== {} ==", name);
}
