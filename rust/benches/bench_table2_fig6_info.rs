//! Table 2 / Fig 6 bench: with/without-info evaluation pipeline (trimmed).
mod common;
use llamea_kt::harness::{evaluate_generated, generate_all, ExpOptions};

fn main() {
    common::section("Table 2 + Fig 6: with/without-info pipeline (trimmed)");
    let opts =
        ExpOptions { runs: 10, gen_runs: 1, llm_calls: 16, seed: 6, ..ExpOptions::default() };
    let t0 = std::time::Instant::now();
    let generated = generate_all(&opts, false);
    let (t2, _, _) = evaluate_generated(&generated, &opts, std::path::Path::new("results"));
    println!("pipeline took {:?}", t0.elapsed());
    println!("{}", t2.to_text());
}
