//! Table 1 bench: constrained search-space construction for all four
//! applications (the substrate cost of every experiment).
mod common;
use llamea_kt::searchspace::Application;

fn main() {
    common::section("Table 1: space construction");
    for app in Application::ALL {
        common::bench(app.name(), 1, if app == Application::Hotspot { 3 } else { 10 }, || {
            let s = app.build_space();
            assert!(s.len() > 0);
        });
    }
    // Regenerate the table itself.
    let t = llamea_kt::harness::table1(std::path::Path::new("results"));
    println!("\n{}", t.to_text());
}
