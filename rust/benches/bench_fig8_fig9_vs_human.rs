//! Fig 8/9 bench: the headline comparison, trimmed to 10 runs/space.
mod common;
use llamea_kt::harness::{fig8_fig9, ExpOptions};

fn main() {
    common::section("Fig 8 + Fig 9: generated vs human-designed (trimmed)");
    let opts =
        ExpOptions { runs: 10, gen_runs: 1, llm_calls: 10, seed: 8, ..ExpOptions::default() };
    let t0 = std::time::Instant::now();
    let (f8, _) = fig8_fig9(&opts, std::path::Path::new("results"));
    println!("full 5-algorithm x 24-space comparison took {:?}", t0.elapsed());
    println!("{}", f8.to_text());
}
