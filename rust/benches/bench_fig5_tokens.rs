//! Fig 5 bench: token accounting through a full (trimmed) generation run.
mod common;
use llamea_kt::harness::{fig5, generate_all, ExpOptions};

fn main() {
    common::section("Fig 5: generation-stage token accounting (trimmed)");
    let opts = ExpOptions { runs: 5, gen_runs: 2, llm_calls: 24, seed: 5, ..ExpOptions::default() };
    let t0 = std::time::Instant::now();
    let generated = generate_all(&opts, false);
    println!("generation of 8 conditions took {:?}", t0.elapsed());
    let t = fig5(&generated, std::path::Path::new("results"));
    println!("{}", t.to_text());
}
