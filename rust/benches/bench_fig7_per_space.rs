//! Fig 7 bench: per-space scoring throughput of one generated algorithm
//! across all 24 spaces.
mod common;
use llamea_kt::llamea::{Genome, GenomeOptimizer};
use llamea_kt::methodology::{run_many, FnFactory, SpaceSetup};

fn main() {
    common::section("Fig 7: per-space evaluation throughput");
    let caches = llamea_kt::tuning::build_all_caches();
    let factory = FnFactory {
        f: || Box::new(GenomeOptimizer::new(Genome::hybrid_vndx_like()))
            as Box<dyn llamea_kt::optimizers::Optimizer>,
        name: "hybrid_vndx_genome".into(),
    };
    for cache in caches.iter().take(8) {
        let setup = SpaceSetup::new(cache);
        common::bench(&cache.id(), 0, 3, || {
            let curves = run_many(cache, &setup, &factory, 10, 3);
            assert_eq!(curves.len(), 10);
        });
    }
}
