//! Hot-path microbenchmarks (§Perf): the primitives every simulated
//! evaluation touches — space construction, membership lookups, neighbor
//! enumeration, cache evaluation, baseline math, and a full optimizer run.
//!
//! Results are also written to `BENCH_hotpath.json` at the repo root
//! (`{name, iters, ns_per_iter}` per section) so the perf trajectory is
//! trackable across PRs; CI uploads the file as an artifact.
mod common;
use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::{Baseline, SpaceSetup};
use llamea_kt::obs;
use llamea_kt::persist;
use llamea_kt::searchspace::{Application, NeighborKind};
use llamea_kt::tuning::{Cache, TuningContext};
use llamea_kt::util::rng::Rng;

fn main() {
    let mut results = Vec::new();
    common::section("hot path");
    let app = Application::Gemm;
    results.push(common::bench("gemm space construction", 1, 5, || {
        assert!(app.build_space().len() > 0);
    }));

    let cache = Cache::build(app, GpuSpec::by_name("A100").unwrap());
    let space = &cache.space;
    let mut rng = Rng::new(1);

    results.push(common::bench("1M index_of lookups", 1, 5, || {
        let mut acc = 0u32;
        for _ in 0..1_000_000 {
            let i = rng.below(space.len()) as u32;
            acc ^= space.index_of(space.config(i)).unwrap();
        }
        std::hint::black_box(acc);
    }));

    // One-time CSR table construction (amortized across every optimizer
    // sharing the Arc<SearchSpace>). The spaces are pre-built outside the
    // timed closure so this series isolates the table build — space
    // enumeration is tracked by "gemm space construction" above.
    let mut fresh_spaces: Vec<_> = (0..3).map(|_| app.build_space()).collect();
    results.push(common::bench("csr hamming table build (gemm)", 0, 3, || {
        let fresh = fresh_spaces.pop().expect("one pre-built space per rep");
        std::hint::black_box(fresh.neighbors_of(0, NeighborKind::Hamming).len());
    }));

    // Row lookups after the table exists (the warmup iteration builds the
    // shared cache's table): this is the ≥5x acceptance target.
    results.push(common::bench("10k hamming neighbor enumerations", 1, 5, || {
        let mut total = 0usize;
        for _ in 0..10_000 {
            let i = rng.below(space.len()) as u32;
            total += space.neighbors_of(i, NeighborKind::Hamming).len();
        }
        std::hint::black_box(total);
    }));

    results.push(common::bench("100k random hamming neighbors", 1, 5, || {
        let mut acc = 0u32;
        for _ in 0..100_000 {
            let i = rng.below(space.len()) as u32;
            if let Some(j) = space.random_neighbor(i, &mut rng, NeighborKind::Hamming) {
                acc ^= j;
            }
        }
        std::hint::black_box(acc);
    }));

    results.push(common::bench("100k simulated evaluations", 1, 5, || {
        let mut ctx = TuningContext::new(&cache, f64::INFINITY, 3);
        for _ in 0..100_000 {
            let i = ctx.rng.below(space.len()) as u32;
            ctx.evaluate(i);
        }
        std::hint::black_box(ctx.unique_evals());
    }));

    results.push(common::bench("cache build gemm@A100", 1, 3, || {
        let c = Cache::build_with_space(
            app,
            GpuSpec::by_name("A100").unwrap(),
            std::sync::Arc::clone(&cache.space),
        );
        std::hint::black_box(c.optimum_ms);
    }));

    let baseline = Baseline::from_cache(&cache);
    results.push(common::bench("baseline budget computation", 1, 10, || {
        std::hint::black_box(baseline.budget_s(0.95));
    }));

    let setup = SpaceSetup::new(&cache);
    results.push(common::bench("one hybrid_vndx run (gemm@A100 budget)", 0, 3, || {
        let mut opt = llamea_kt::optimizers::by_name("hybrid_vndx").unwrap();
        let mut ctx = TuningContext::new(&cache, setup.budget_s, 9);
        opt.run(&mut ctx);
        std::hint::black_box(ctx.unique_evals());
    }));

    // Persistent cache store: cold full build vs save vs the zero-copy
    // warm path (load_space + load_cache, both mmap) for the heaviest
    // application. Acceptance target: cache_load_mmap ≥10× faster than
    // cache_cold_build.
    common::section("persistent cache store (hotspot@A100)");
    let hs = Application::Hotspot;
    let hs_gpu = GpuSpec::by_name("A100").unwrap();
    let hs_cache = Cache::build(hs, hs_gpu);
    let store = std::env::temp_dir().join(format!("llkt-bench-persist-{}", std::process::id()));
    std::fs::create_dir_all(&store).unwrap();
    let hs_space_path = persist::space_path(&store, hs);
    let hs_cache_path = persist::cache_path(&store, hs, hs_gpu.name);
    persist::save_space(&hs_space_path, &hs_cache.space).unwrap();

    let cold = common::bench("cache_cold_build hotspot@A100", 0, 3, || {
        let c = Cache::build(hs, hs_gpu);
        std::hint::black_box(c.optimum_ms);
    });
    results.push(common::bench("cache_save hotspot@A100", 1, 3, || {
        persist::save_cache(&hs_cache_path, &hs_cache).unwrap();
    }));
    let warm = common::bench("cache_load_mmap hotspot@A100", 1, 5, || {
        let s = persist::load_space(&hs_space_path, hs, persist::LoadMode::Mmap).unwrap();
        let c = persist::load_cache(
            &hs_cache_path,
            hs,
            hs_gpu,
            std::sync::Arc::new(s),
            persist::LoadMode::Mmap,
        )
        .unwrap();
        std::hint::black_box(c.optimum_ms);
    });
    println!(
        "cache_load_mmap is {:.1}x faster than cache_cold_build (target: >=10x)",
        cold.ns_per_iter / warm.ns_per_iter
    );
    results.push(cold);
    results.push(warm);
    let _ = std::fs::remove_dir_all(&store);

    // Portfolio-racing overhead: the bandit's rung-boundary decision over
    // a 16-arm roster (reward ingestion + UCB ranking + halving keep),
    // and the GP surrogate's fit-plus-acquisition step at the largest
    // train_window the domain grid allows. Both sit on the tuning control
    // path, so their per-step cost must stay microseconds.
    common::section("racing");
    results.push(common::bench("bandit_step 16-arm decision", 1, 10, || {
        use llamea_kt::coordinator::{decide, rung_rewards, Bandit};
        let mut acc = 0usize;
        for round in 0..1_000u64 {
            let mut bandit = Bandit::new(16);
            let live: Vec<usize> = (0..16).collect();
            let inputs: Vec<(usize, f64, f64, f64)> = (0..16)
                .map(|a| (a, 0.5 + ((a as u64 + round) % 7) as f64 * 0.05, 0.4, 30.0))
                .collect();
            let rewards = rung_rewards(&inputs);
            let last: Vec<f64> = inputs.iter().map(|&(_, s, _, _)| s).collect();
            let (survivors, _) = decide(&mut bandit, &live, &rewards, &last, 2);
            acc += survivors.len();
        }
        std::hint::black_box(acc);
    }));

    let gp_points: Vec<(Vec<f64>, f64)> = {
        let mut rng = Rng::new(7);
        let mut pts = Vec::with_capacity(96);
        while pts.len() < 96 {
            let i = rng.below(space.len()) as u32;
            let y = cache.mean_ms[i as usize];
            if y.is_finite() {
                pts.push((space.values_f64(i), y));
            }
        }
        pts
    };
    results.push(common::bench("gp_fit_predict 96pts + 1k EI queries", 1, 5, || {
        use llamea_kt::optimizers::bayes_opt::fit_gp;
        let gp = fit_gp(&gp_points, 2.0).expect("bench window must be fittable");
        let mut acc = 0.0;
        for (x, _) in gp_points.iter().cycle().take(1_000) {
            acc += gp.expected_improvement(x, 0.01);
        }
        std::hint::black_box(acc);
    }));

    // Observability recorder: the disabled hot path is the one every
    // span call site pays in a normal run (contract: one relaxed atomic
    // load, no clock read); the enabled rows show what a recorded span
    // actually costs under metrics aggregation and full tracing.
    common::section("obs_overhead");
    results.push(common::bench("100k obs spans (disabled)", 1, 5, || {
        for i in 0..100_000u64 {
            drop(obs::span("bench.span").kv("i", i));
        }
    }));
    obs::enable(false, true);
    results.push(common::bench("100k obs spans (metrics)", 1, 5, || {
        for i in 0..100_000u64 {
            drop(obs::span("bench.span").kv("i", i));
        }
    }));
    obs::enable(true, false);
    results.push(common::bench("100k obs spans (trace)", 1, 3, || {
        for i in 0..100_000u64 {
            drop(obs::span("bench.span").kv("i", i));
        }
        // Truncate between reps so the event buffer stays flat; the
        // clear is O(events) and negligible next to the records.
        obs::reset();
    }));
    obs::enable(false, false);
    obs::reset();

    // Fleet wire codec: one curve row (the dominant line type on a fleet
    // connection) serialized to its newline-delimited JSON form, and the
    // coordinator-side parse back to a typed event. Both sit on the
    // streaming path of every remotely executed job, so they must stay
    // far below the cost of the tuning run that produced the row.
    common::section("wire_codec");
    let wire_curve: Vec<f64> = (0..1_000).map(|i| 1.0 + (i as f64) * 1.5e-3).collect();
    results.push(common::bench("wire_codec row serialize 1k-point curve", 1, 5, || {
        use llamea_kt::remote::protocol::row_event;
        let mut bytes = 0usize;
        for i in 0..100usize {
            bytes += row_event(i, i % 4, &wire_curve).to_string().len();
        }
        std::hint::black_box(bytes);
    }));
    let wire_line = {
        use llamea_kt::remote::protocol::row_event;
        row_event(42, 3, &wire_curve).to_string()
    };
    results.push(common::bench("wire_codec row parse 1k-point curve", 1, 5, || {
        use llamea_kt::remote::protocol::{parse_event, WorkerEvent};
        let mut acc = 0usize;
        for _ in 0..100usize {
            match parse_event(&wire_line).expect("row line parses") {
                WorkerEvent::Row { curve, .. } => acc += curve.len(),
                other => panic!("expected row, got {:?}", other),
            }
        }
        std::hint::black_box(acc);
    }));

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    common::write_json(&out, &results);
}
