//! Hot-path microbenchmarks (§Perf): the primitives every simulated
//! evaluation touches — space construction, membership lookups, neighbor
//! enumeration, cache evaluation, baseline math, and a full optimizer run.
mod common;
use llamea_kt::kernels::gpu::GpuSpec;
use llamea_kt::methodology::{Baseline, SpaceSetup};
use llamea_kt::searchspace::{Application, NeighborKind};
use llamea_kt::tuning::{Cache, TuningContext};
use llamea_kt::util::rng::Rng;

fn main() {
    common::section("hot path");
    let app = Application::Gemm;
    common::bench("gemm space construction", 1, 5, || {
        assert!(app.build_space().len() > 0);
    });

    let cache = Cache::build(app, GpuSpec::by_name("A100").unwrap());
    let space = &cache.space;
    let mut rng = Rng::new(1);

    common::bench("1M index_of lookups", 1, 5, || {
        let mut acc = 0u32;
        for _ in 0..1_000_000 {
            let i = rng.below(space.len()) as u32;
            acc ^= space.index_of(space.config(i)).unwrap();
        }
        std::hint::black_box(acc);
    });

    common::bench("10k hamming neighbor enumerations", 1, 5, || {
        let mut total = 0usize;
        for _ in 0..10_000 {
            let i = rng.below(space.len()) as u32;
            total += space.neighbors(i, NeighborKind::Hamming).len();
        }
        std::hint::black_box(total);
    });

    common::bench("100k simulated evaluations", 1, 5, || {
        let mut ctx = TuningContext::new(&cache, f64::INFINITY, 3);
        for _ in 0..100_000 {
            let i = ctx.rng.below(space.len()) as u32;
            ctx.evaluate(i);
        }
        std::hint::black_box(ctx.unique_evals());
    });

    common::bench("cache build gemm@A100", 1, 3, || {
        let c = Cache::build_with_space(
            app,
            GpuSpec::by_name("A100").unwrap(),
            std::sync::Arc::clone(&cache.space),
        );
        std::hint::black_box(c.optimum_ms);
    });

    let baseline = Baseline::from_cache(&cache);
    common::bench("baseline budget computation", 1, 10, || {
        std::hint::black_box(baseline.budget_s(0.95));
    });

    let setup = SpaceSetup::new(&cache);
    common::bench("one hybrid_vndx run (gemm@A100 budget)", 0, 3, || {
        let mut opt = llamea_kt::optimizers::by_name("hybrid_vndx").unwrap();
        let mut ctx = TuningContext::new(&cache, setup.budget_s, 9);
        opt.run(&mut ctx);
        std::hint::black_box(ctx.unique_evals());
    });
}
